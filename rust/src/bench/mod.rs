//! Bench harness (the vendor set has no criterion, so `cargo bench`
//! targets are `harness = false` binaries built on this module).
//!
//! Provides warmup + repeated measurement with order statistics, and the
//! experiment-table printer used by every `benches/*.rs` target to emit
//! the paper-style rows recorded in EXPERIMENTS.md.

use crate::util::{human_duration, Summary};
use std::time::Instant;

/// Measurement options.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub measure_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup_iters: 2, measure_iters: 7 }
    }
}

impl BenchOpts {
    /// Quick mode for CI smoke runs (`sar tune --fast`).
    pub fn fast() -> Self {
        Self { warmup_iters: 1, measure_iters: 3 }
    }
}

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        self.secs.p50
    }
}

/// Run `f` with warmup and return timing stats. `f` should perform one
/// complete operation per call.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.measure_iters);
    for _ in 0..opts.measure_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult { name: name.to_string(), secs: Summary::of(&samples) };
    eprintln!(
        "  bench {:<40} p10 {:>12}  p50 {:>12}  p90 {:>12}  (n={})",
        r.name,
        human_duration(r.secs.p10),
        human_duration(r.secs.p50),
        human_duration(r.secs.p90),
        r.secs.n
    );
    r
}

// --- machine-readable output (BENCH_*.json rows) -------------------------
//
// The vendor set has no serde; these helpers emit the small, fixed-shape
// JSON the bench trajectory files need. Rows always carry p10/p50/p90 so
// the recorded trajectory captures spread, not just a point estimate.

/// A JSON number literal (JSON has no NaN/Inf: those serialize as 0).
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        let s = format!("{v}");
        // `{}` prints integral floats without a dot; keep them valid
        // JSON numbers either way (they are), but normalize -0.
        if s == "-0" {
            "0".to_string()
        } else {
            s
        }
    } else {
        "0".to_string()
    }
}

/// A JSON string literal with the required escapes.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A [`Summary`] as a JSON object with the spread percentiles.
pub fn summary_json(s: &Summary) -> String {
    format!(
        "{{\"n\":{},\"mean\":{},\"min\":{},\"p10\":{},\"p50\":{},\"p90\":{},\"max\":{}}}",
        s.n,
        json_f64(s.mean),
        json_f64(s.min),
        json_f64(s.p10),
        json_f64(s.p50),
        json_f64(s.p90),
        json_f64(s.max)
    )
}

/// Print a section header for a paper experiment.
pub fn section(experiment: &str, description: &str) {
    println!("\n## {experiment}");
    println!("{description}\n");
}

/// Print a markdown table (convenience wrapper over `obs::Table`).
pub fn print_table(header: &[&str], rows: &[Vec<String>]) {
    let mut t = crate::obs::Table::new(header);
    for r in rows {
        t.row(r.clone());
    }
    print!("{}", t.to_markdown());
}

/// Throughput in the paper's unit: billions of input values reduced per
/// second (§VI-B).
pub fn throughput_bvals_per_sec(total_values: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    total_values as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0usize;
        let opts = BenchOpts { warmup_iters: 3, measure_iters: 5 };
        let r = bench("noop", &opts, || {
            count += 1;
        });
        assert_eq!(count, 8);
        assert_eq!(r.secs.n, 5);
        assert!(r.median() >= 0.0);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput_bvals_per_sec(2_000_000_000, 2.0) - 1.0).abs() < 1e-9);
        assert_eq!(throughput_bvals_per_sec(100, 0.0), 0.0);
    }

    #[test]
    fn json_emission_is_wellformed() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "0");
        assert_eq!(json_f64(f64::INFINITY), "0");
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        let j = summary_json(&s);
        assert!(j.starts_with('{') && j.ends_with('}'));
        for key in ["\"n\":3", "\"p10\":", "\"p50\":2", "\"p90\":", "\"min\":1", "\"max\":3"] {
            assert!(j.contains(key), "{j} missing {key}");
        }
    }

    #[test]
    fn fast_opts_are_smaller() {
        let f = BenchOpts::fast();
        let d = BenchOpts::default();
        assert!(f.warmup_iters < d.warmup_iters && f.measure_iters < d.measure_iters);
    }
}
