//! Human-readable formatting helpers for metrics and bench tables.

/// Format a byte count with binary units: `17301504 → "16.5 MiB"`.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a count with decimal suffixes: `1500000000 → "1.50B"`.
pub fn human_count(n: u64) -> String {
    const UNITS: [(&str, u64); 4] =
        [("T", 1_000_000_000_000), ("B", 1_000_000_000), ("M", 1_000_000), ("K", 1_000)];
    for (suffix, scale) in UNITS {
        if n >= scale {
            return format!("{:.2}{suffix}", n as f64 / scale as f64);
        }
    }
    n.to_string()
}

/// Format a duration in adaptive units.
pub fn human_duration(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(17_301_504), "16.5 MiB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.0 GiB");
    }

    #[test]
    fn count_units() {
        assert_eq!(human_count(7), "7");
        assert_eq!(human_count(1_500), "1.50K");
        assert_eq!(human_count(60_000_000), "60.00M");
        assert_eq!(human_count(1_500_000_000), "1.50B");
    }

    #[test]
    fn duration_units() {
        assert_eq!(human_duration(2.5), "2.500 s");
        assert_eq!(human_duration(0.0025), "2.500 ms");
        assert_eq!(human_duration(2.5e-6), "2.500 µs");
        assert_eq!(human_duration(2.5e-8), "25.0 ns");
    }
}
