//! Summary statistics over f64 samples (median / percentiles / mean),
//! shared by the metrics module and the bench harness.

/// Order statistics summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute the summary; returns a zeroed summary for empty input.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p10: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut xs: Vec<f64> = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| -> f64 {
            // Nearest-rank with linear interpolation.
            if n == 1 {
                return xs[0];
            }
            let rank = p * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p10: pct(0.10),
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            max: xs[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[3.5]);
        assert_eq!(s.p50, 3.5);
        assert_eq!(s.min, 3.5);
        assert_eq!(s.max, 3.5);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn known_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(s.p10 < s.p50 && s.p50 < s.p90 && s.p90 <= s.p99);
    }

    #[test]
    fn order_invariant() {
        let a = Summary::of(&[3.0, 1.0, 2.0]);
        let b = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(a, b);
    }
}
