//! Checksums for the on-disk shard format: CRC-32 (IEEE) for per-shard
//! payload integrity and FNV-1a/64 for the manifest digest.
//!
//! The offline vendor set has no `crc32fast`/`twox-hash`, so both are
//! implemented here. CRC-32 uses the standard reflected table algorithm;
//! FNV-1a is the usual multiply-xor fold. Neither is cryptographic —
//! they guard against truncation, bit-rot and copy mistakes, not
//! adversaries.

/// Reflected CRC-32 (IEEE 802.3) lookup table, built at compile time.
const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_crc_table();

/// Incremental CRC-32 (IEEE): feed bytes with [`Crc32::update`], read the
/// digest with [`Crc32::finish`] (non-destructive — streaming readers
/// compare mid-stream states against nothing, only the final value).
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = (s >> 8) ^ CRC_TABLE[((s ^ b as u32) & 0xFF) as usize];
        }
        self.state = s;
    }

    pub fn finish(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// FNV-1a 64-bit hash — the shard-manifest digest primitive.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn crc32_streaming_equals_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn crc32_detects_single_bit_flips() {
        let mut data = vec![0x5Au8; 256];
        let want = crc32(&data);
        data[100] ^= 0x01;
        assert_ne!(crc32(&data), want);
    }

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_F739_67E8);
    }
}
