//! Small shared substrates: deterministic RNG + Zipf sampling, logging,
//! timing, and human-readable formatting.
//!
//! The offline build has no `rand`, `env_logger` or `humansize`, so these
//! are implemented in-repo.

pub mod crc;
pub mod fmt;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use crc::{crc32, fnv1a64, Crc32};
pub use fmt::{human_bytes, human_count, human_duration};
pub use rng::{Pcg32, SplitMix64, Zipf};
pub use stats::Summary;
pub use timer::{ScopedTimer, Stopwatch};
