//! Minimal `log` facade backend (the vendor set has `log` but no
//! `env_logger`). Verbosity is controlled by `SAR_LOG` (error|warn|info|
//! debug|trace) or programmatically via [`init_with_level`].

use log::{Level, LevelFilter, Metadata, Record};
use std::sync::Once;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the stderr logger once; level from `SAR_LOG` (default `info`).
pub fn init() {
    let level = match std::env::var("SAR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    init_with_level(level);
}

/// Install the stderr logger with an explicit level (first call wins).
pub fn init_with_level(level: LevelFilter) {
    INIT.call_once(|| {
        let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init_with_level(LevelFilter::Warn);
        init(); // second call must not panic
        log::warn!("logging smoke test");
    }
}
