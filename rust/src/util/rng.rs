//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set does not include the `rand` crate, so we implement
//! the small set of generators the library needs from scratch:
//!
//! * [`SplitMix64`] — seed expander / fast 64-bit stream (Steele et al.).
//! * [`Pcg32`] — PCG-XSH-RR 64/32 (O'Neill), the workhorse generator.
//! * Distribution helpers: uniform ranges, `f64`/`f32` in `[0,1)`,
//!   exponential, log-normal-ish outliers, and shuffling.
//!
//! All generators are deterministic given a seed, which the test suite and
//! the benchmark harness rely on for reproducibility.

/// SplitMix64: used to expand user seeds into full generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid PRNG.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed; the stream id is derived from the
    /// seed so distinct seeds give decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::from_state(sm.next_u64(), sm.next_u64())
    }

    /// Derive a child generator (e.g. one per worker thread) that is
    /// decorrelated from `self` and from other children.
    pub fn fork(&mut self, salt: u64) -> Pcg32 {
        let a = self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg32::new(a)
    }

    pub fn from_state(state: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(state);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift with
    /// rejection to remove modulo bias.
    #[inline]
    pub fn gen_range_u32(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` for 64-bit bounds.
    #[inline]
    pub fn gen_range_u64(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit Lemire reduction.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range_u64((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard exponential variate (mean 1).
    #[inline]
    pub fn next_exp(&mut self) -> f64 {
        // Inverse CDF; clamp away from 0 to avoid ln(0).
        let u = self.next_f64().max(1e-18);
        -u.ln()
    }

    /// Standard normal via Box–Muller (one sample per call; the sibling is
    /// discarded — simplicity over throughput, this is not a hot path).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-18);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_u64((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed; uses a
    /// retry set for small k, partial shuffle otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.gen_range(0, n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

/// Bounded Zipf(α) sampler over `{0, 1, …, n−1}` (element `i` has weight
/// `(i+1)^−α`), using Hörmann & Derflinger rejection-inversion. Valid for
/// `α > 0`, `n ≥ 1`. This is the degree distribution generator behind the
/// synthetic power-law datasets (paper §I, eq. 1).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    // Precomputed constants for rejection-inversion.
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1, "Zipf support must be non-empty");
        assert!(alpha > 0.0, "Zipf exponent must be positive");
        let h = |x: f64| -> f64 {
            // H(x) = integral of x^-alpha
            if (alpha - 1.0).abs() < 1e-12 {
                x.ln()
            } else {
                (x.powf(1.0 - alpha) - 1.0) / (1.0 - alpha)
            }
        };
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        let s = 2.0 - Self::h_inv_static(alpha, h(2.5) - (2.0f64).powf(-alpha));
        Zipf { n, alpha, h_x1, h_n, s }
    }

    fn h_inv_static(alpha: f64, x: f64) -> f64 {
        if (alpha - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - alpha)).powf(1.0 / (1.0 - alpha))
        }
    }

    fn h(&self, x: f64) -> f64 {
        if (self.alpha - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - self.alpha) - 1.0) / (1.0 - self.alpha)
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        Self::h_inv_static(self.alpha, x)
    }

    /// Draw a sample in `[0, n)`. Rank 0 is the most frequent element.
    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            if k - x <= self.s || u >= self.h(k + 0.5) - k.powf(-self.alpha) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_seed_sensitivity() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams from different seeds should diverge");
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.gen_range(3, 17);
            assert!((3..17).contains(&x));
        }
        for _ in 0..10_000 {
            assert!(rng.gen_range_u32(1) == 0);
            assert!(rng.gen_range_u64(1) == 0);
        }
    }

    #[test]
    fn uniform_f64_mean() {
        let mut rng = Pcg32::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_u32_uniformity() {
        let mut rng = Pcg32::new(5);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.gen_range_u32(10) as usize] += 1;
        }
        for &c in &counts {
            let expected = n / 10;
            assert!(
                (c as i64 - expected as i64).abs() < (expected as i64) / 10,
                "bucket count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn exp_mean_one() {
        let mut rng = Pcg32::new(13);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.next_exp()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(23);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg32::new(29);
        let s = rng.sample_distinct(1000, 50);
        assert_eq!(s.len(), 50);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 50);
        assert!(s.iter().all(|&x| x < 1000));
        // Dense case path
        let s2 = rng.sample_distinct(10, 9);
        assert_eq!(s2.len(), 9);
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Pcg32::new(31);
        let z = Zipf::new(1000, 1.2);
        let n = 100_000;
        let mut count0 = 0usize;
        let mut count_tail = 0usize;
        for _ in 0..n {
            let x = z.sample(&mut rng);
            assert!(x < 1000);
            if x == 0 {
                count0 += 1;
            }
            if x >= 500 {
                count_tail += 1;
            }
        }
        // Rank 0 must dominate any individual tail rank by a lot.
        assert!(count0 > n / 100, "head rank too rare: {count0}");
        assert!(count0 > count_tail / 20, "distribution not skewed enough");
    }

    #[test]
    fn zipf_alpha_one_edge() {
        let mut rng = Pcg32::new(37);
        let z = Zipf::new(100, 1.0);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn zipf_ratio_matches_power_law() {
        // P(0)/P(1) should be close to 2^alpha.
        let mut rng = Pcg32::new(41);
        let alpha = 2.0;
        let z = Zipf::new(10_000, alpha);
        let n = 400_000;
        let (mut c0, mut c1) = (0f64, 0f64);
        for _ in 0..n {
            match z.sample(&mut rng) {
                0 => c0 += 1.0,
                1 => c1 += 1.0,
                _ => {}
            }
        }
        let ratio = c0 / c1;
        let expect = 2f64.powf(alpha);
        assert!(
            (ratio - expect).abs() / expect < 0.1,
            "ratio={ratio} expect={expect}"
        );
    }

    #[test]
    fn fork_decorrelated() {
        let mut root = Pcg32::new(55);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
