//! Wall-clock timing helpers used by the metrics module and the bench
//! harness.

use std::time::{Duration, Instant};

/// A resettable stopwatch accumulating named phases.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now(), laps: Vec::new() }
    }

    /// Record the time since the last lap (or construction) under `name`
    /// and restart the lap clock.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.start;
        self.laps.push((name.to_string(), d));
        self.start = now;
        d
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    /// Total of all recorded laps.
    pub fn total(&self) -> Duration {
        self.laps.iter().map(|(_, d)| *d).sum()
    }

    /// Sum of laps whose name matches `name`.
    pub fn total_of(&self, name: &str) -> Duration {
        self.laps.iter().filter(|(n, _)| n == name).map(|(_, d)| *d).sum()
    }
}

/// RAII timer: logs the elapsed time at `debug` level on drop.
pub struct ScopedTimer {
    label: String,
    start: Instant,
}

impl ScopedTimer {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        log::debug!("{}: {:?}", self.label, self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("b");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.total_of("a") >= Duration::from_millis(3));
        assert!(sw.total() >= sw.total_of("a") + sw.total_of("b"));
    }

    #[test]
    fn scoped_timer_elapsed_monotone() {
        let t = ScopedTimer::new("x");
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.elapsed() >= Duration::from_millis(1));
    }
}
