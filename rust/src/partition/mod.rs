//! Data partitioning (paper §II-B, §III-A).
//!
//! * [`hash::IndexHasher`] — the invertible random permutation applied to
//!   vertex indices before everything else, so that contiguous range
//!   splits behave like random vertex partitions.
//! * [`edge::random_edge_partition`] — random edge partitioning, the
//!   scheme the paper uses for natural graphs (vertex partitioning is
//!   known to be ineffective for power-law data).
//! * [`range`] — contiguous range covers used by the butterfly layers.

pub mod edge;
pub mod hash;
pub mod range;

pub use edge::{greedy_edge_partition, random_edge_partition, shard_stats, ShardStats};
pub use hash::IndexHasher;
pub use range::RangeCover;

use anyhow::{bail, Result};

/// Which edge-partitioning scheme to use (the `sar shard --partition`
/// knob; the in-memory PageRank drivers always use [`Strategy::Random`],
/// the paper's choice for data "sitting in the network").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Uniform random edge assignment (paper §II-B default).
    Random,
    /// PowerGraph's greedy heuristic (~15-20% shorter vertex lists).
    Greedy,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "random" => Ok(Strategy::Random),
            "greedy" => Ok(Strategy::Greedy),
            other => bail!("unknown partition strategy `{other}` (random|greedy)"),
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            Strategy::Random => "random",
            Strategy::Greedy => "greedy",
        }
    }

    /// Partition `edges` into `m` shards. `vertices` and `seed` feed the
    /// greedy and random schemes respectively.
    pub fn partition(
        &self,
        edges: &[(i64, i64)],
        m: usize,
        vertices: i64,
        seed: u64,
    ) -> Result<Vec<Vec<(i64, i64)>>> {
        if m == 0 {
            bail!("cannot partition into 0 shards");
        }
        match self {
            Strategy::Random => Ok(random_edge_partition(edges, m, seed)),
            Strategy::Greedy => {
                if m > 64 {
                    bail!("greedy partitioning supports at most 64 shards, got {m}");
                }
                Ok(greedy_edge_partition(edges, m, vertices))
            }
        }
    }
}
