//! Data partitioning (paper §II-B, §III-A).
//!
//! * [`hash::IndexHasher`] — the invertible random permutation applied to
//!   vertex indices before everything else, so that contiguous range
//!   splits behave like random vertex partitions.
//! * [`edge::random_edge_partition`] — random edge partitioning, the
//!   scheme the paper uses for natural graphs (vertex partitioning is
//!   known to be ineffective for power-law data).
//! * [`range`] — contiguous range covers used by the butterfly layers.

pub mod edge;
pub mod hash;
pub mod range;

pub use edge::{greedy_edge_partition, random_edge_partition, shard_stats, ShardStats};
pub use hash::IndexHasher;
pub use range::RangeCover;
