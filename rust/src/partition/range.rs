//! Contiguous range covers: the hierarchical interval refinement used by
//! the butterfly layers.
//!
//! Because indices are hash-permuted, splitting `[0, R)` into equal
//! contiguous intervals is statistically a random partition, but is
//! computable with binary searches instead of shuffles (paper §III-A).

/// An interval `[lo, hi)` split into `k` near-equal sub-intervals.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RangeCover {
    pub lo: i64,
    pub hi: i64,
    pub bounds: Vec<i64>, // k+1 entries, bounds[0]=lo, bounds[k]=hi
}

impl RangeCover {
    /// Split `[lo, hi)` into `k` near-equal parts. Sub-interval `j` is
    /// `[bounds[j], bounds[j+1])`; sizes differ by at most 1.
    pub fn split(lo: i64, hi: i64, k: usize) -> RangeCover {
        assert!(hi >= lo, "inverted range");
        assert!(k >= 1, "k must be positive");
        let n = (hi - lo) as u128;
        let mut bounds = Vec::with_capacity(k + 1);
        for j in 0..=k as u128 {
            bounds.push(lo + (n * j / k as u128) as i64);
        }
        RangeCover { lo, hi, bounds }
    }

    pub fn k(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Sub-interval `j` as `(lo, hi)`.
    pub fn part(&self, j: usize) -> (i64, i64) {
        (self.bounds[j], self.bounds[j + 1])
    }

    /// Which sub-interval an index falls into.
    pub fn locate(&self, idx: i64) -> usize {
        assert!(idx >= self.lo && idx < self.hi, "index outside cover");
        // partition_point over bounds[1..k]
        let inner = &self.bounds[1..self.bounds.len() - 1];
        inner.partition_point(|&b| b <= idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_even() {
        let c = RangeCover::split(0, 12, 4);
        assert_eq!(c.bounds, vec![0, 3, 6, 9, 12]);
        assert_eq!(c.k(), 4);
        assert_eq!(c.part(2), (6, 9));
    }

    #[test]
    fn split_uneven_sizes_differ_by_one() {
        let c = RangeCover::split(0, 10, 3);
        let sizes: Vec<i64> = (0..3).map(|j| c.part(j).1 - c.part(j).0).collect();
        assert_eq!(sizes.iter().sum::<i64>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn split_large_range_no_overflow() {
        let c = RangeCover::split(0, i64::MAX / 2, 7);
        assert_eq!(c.bounds[0], 0);
        assert_eq!(*c.bounds.last().unwrap(), i64::MAX / 2);
        assert!(c.bounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn locate_matches_part() {
        let c = RangeCover::split(100, 200, 6);
        for idx in 100..200 {
            let j = c.locate(idx);
            let (lo, hi) = c.part(j);
            assert!(idx >= lo && idx < hi, "{idx} misplaced into part {j}");
        }
    }

    #[test]
    fn k_one_identity() {
        let c = RangeCover::split(5, 25, 1);
        assert_eq!(c.bounds, vec![5, 25]);
        assert_eq!(c.locate(24), 0);
    }

    #[test]
    fn empty_range() {
        let c = RangeCover::split(7, 7, 3);
        assert_eq!(c.bounds, vec![7, 7, 7, 7]);
    }
}
