//! Random edge partitioning (paper §II-B).
//!
//! PowerGraph showed edge partitioning beats vertex partitioning for
//! power-law graphs; the paper uses *random* edge partitioning ("more
//! typically the case for data sitting in the network") and estimates
//! greedy partitioning would improve communication a further 15–20%.

use crate::util::Pcg32;

/// Assign each edge to one of `m` shards uniformly at random.
/// Returns per-shard edge lists. Deterministic given `seed`.
pub fn random_edge_partition(
    edges: &[(i64, i64)],
    m: usize,
    seed: u64,
) -> Vec<Vec<(i64, i64)>> {
    assert!(m >= 1);
    let mut rng = Pcg32::new(seed);
    let mut shards: Vec<Vec<(i64, i64)>> = (0..m)
        .map(|_| Vec::with_capacity(edges.len() / m + 1))
        .collect();
    for &e in edges {
        shards[rng.gen_range(0, m)].push(e);
    }
    shards
}

/// Greedy edge partitioning (PowerGraph's heuristic, paper §II-B/§VI-E:
/// "PowerGraph uses greedily partitioned graph which produces shorter
/// vertex lists (and communication) on each node … should improve by
/// about 15-20%"). Each edge goes to the shard that minimizes new vertex
/// replicas: both endpoints present ≻ one present ≻ least-loaded.
pub fn greedy_edge_partition(
    edges: &[(i64, i64)],
    m: usize,
    vertices: i64,
) -> Vec<Vec<(i64, i64)>> {
    assert!(m >= 1);
    let mut shards: Vec<Vec<(i64, i64)>> =
        (0..m).map(|_| Vec::with_capacity(edges.len() / m + 1)).collect();
    // presence[v] = bitmask of shards already holding v (m ≤ 64 supported;
    // larger m falls back to random assignment for the overflow shards)
    assert!(m <= 64, "greedy partitioner supports up to 64 shards");
    let mut presence = vec![0u64; vertices as usize];
    for &(u, v) in edges {
        let pu = presence[u as usize];
        let pv = presence[v as usize];
        let both = pu & pv;
        let either = pu | pv;
        let candidates = if both != 0 {
            both
        } else if either != 0 {
            either
        } else {
            u64::MAX >> (64 - m)
        };
        // least-loaded among candidate shards
        let mut best = usize::MAX;
        let mut best_load = usize::MAX;
        for s in 0..m {
            if candidates & (1u64 << s) != 0 && shards[s].len() < best_load {
                best = s;
                best_load = shards[s].len();
            }
        }
        shards[best].push((u, v));
        presence[u as usize] |= 1u64 << best;
        presence[v as usize] |= 1u64 << best;
    }
    shards
}

/// Partition statistics for Table I: per-shard distinct-vertex counts.
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Distinct vertices (src or dst) appearing in each shard.
    pub verts_per_shard: Vec<usize>,
    /// Distinct source vertices per shard.
    pub srcs_per_shard: Vec<usize>,
    /// Distinct destination vertices per shard.
    pub dsts_per_shard: Vec<usize>,
    /// Edges per shard.
    pub edges_per_shard: Vec<usize>,
}

/// Compute per-shard vertex stats (drives Table I's "Partition # of
/// vertices / Percentage of total vertices").
pub fn shard_stats(shards: &[Vec<(i64, i64)>]) -> ShardStats {
    let mut verts = Vec::with_capacity(shards.len());
    let mut srcs = Vec::with_capacity(shards.len());
    let mut dsts = Vec::with_capacity(shards.len());
    let mut edges = Vec::with_capacity(shards.len());
    for shard in shards {
        let mut s: Vec<i64> = shard.iter().map(|&(u, _)| u).collect();
        s.sort_unstable();
        s.dedup();
        let mut d: Vec<i64> = shard.iter().map(|&(_, v)| v).collect();
        d.sort_unstable();
        d.dedup();
        let mut all: Vec<i64> = s.iter().chain(d.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        srcs.push(s.len());
        dsts.push(d.len());
        verts.push(all.len());
        edges.push(shard.len());
    }
    ShardStats { verts_per_shard: verts, srcs_per_shard: srcs, dsts_per_shard: dsts, edges_per_shard: edges }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_edges(n: usize) -> Vec<(i64, i64)> {
        let mut rng = Pcg32::new(5);
        (0..n).map(|_| (rng.gen_range(0, 100) as i64, rng.gen_range(0, 100) as i64)).collect()
    }

    #[test]
    fn partition_preserves_all_edges() {
        let edges = toy_edges(10_000);
        let shards = random_edge_partition(&edges, 8, 1);
        assert_eq!(shards.len(), 8);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, edges.len());
        // multiset equality
        let mut orig = edges.clone();
        let mut recon: Vec<(i64, i64)> = shards.concat();
        orig.sort_unstable();
        recon.sort_unstable();
        assert_eq!(orig, recon);
    }

    #[test]
    fn partition_is_balanced() {
        let edges = toy_edges(80_000);
        let shards = random_edge_partition(&edges, 16, 2);
        for s in &shards {
            let expected = 5_000i64;
            assert!(
                (s.len() as i64 - expected).abs() < expected / 5,
                "shard size {} too far from {expected}",
                s.len()
            );
        }
    }

    #[test]
    fn partition_deterministic() {
        let edges = toy_edges(1000);
        let a = random_edge_partition(&edges, 4, 9);
        let b = random_edge_partition(&edges, 4, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn single_shard() {
        let edges = toy_edges(100);
        let shards = random_edge_partition(&edges, 1, 0);
        assert_eq!(shards[0], edges);
    }

    #[test]
    fn greedy_preserves_edges_and_beats_random() {
        // power-law-ish edges over 200 vertices
        let mut rng = Pcg32::new(77);
        let zipf = crate::util::Zipf::new(200, 1.2);
        let edges: Vec<(i64, i64)> = (0..20_000)
            .map(|_| {
                loop {
                    let u = zipf.sample(&mut rng) as i64;
                    let v = zipf.sample(&mut rng) as i64;
                    if u != v {
                        return (u, v);
                    }
                }
            })
            .collect();
        let m = 16;
        let greedy = greedy_edge_partition(&edges, m, 200);
        let random = random_edge_partition(&edges, m, 1);
        // multiset of edges preserved
        let total: usize = greedy.iter().map(|s| s.len()).sum();
        assert_eq!(total, edges.len());
        // greedy must shorten the mean per-shard vertex list (the paper's
        // 15-20% claim; we only require a strict improvement)
        let mean = |st: &ShardStats| {
            st.verts_per_shard.iter().sum::<usize>() as f64 / st.verts_per_shard.len() as f64
        };
        let g = mean(&shard_stats(&greedy));
        let r = mean(&shard_stats(&random));
        assert!(g < r, "greedy ({g:.1}) should beat random ({r:.1})");
        // and stay reasonably balanced (within 4x of even)
        let max_shard = greedy.iter().map(|s| s.len()).max().unwrap();
        assert!(max_shard < 4 * edges.len() / m, "greedy too unbalanced: {max_shard}");
    }

    #[test]
    fn greedy_single_shard_and_empty() {
        let edges = vec![(0i64, 1i64), (1, 2)];
        let g = greedy_edge_partition(&edges, 1, 3);
        assert_eq!(g[0], edges);
        let e = greedy_edge_partition(&[], 4, 10);
        assert!(e.iter().all(|s| s.is_empty()));
    }

    #[test]
    fn stats_counts_distinct() {
        let shards = vec![vec![(1, 2), (1, 3), (2, 3)], vec![(5, 5)]];
        let st = shard_stats(&shards);
        assert_eq!(st.srcs_per_shard, vec![2, 1]);
        assert_eq!(st.dsts_per_shard, vec![2, 1]);
        assert_eq!(st.verts_per_shard, vec![3, 1]);
        assert_eq!(st.edges_per_shard, vec![3, 1]);
    }
}
