//! Invertible pseudo-random permutation of vertex indices.
//!
//! Paper §III-A: "To avoid clustering of high-degree vertices with similar
//! indices, we first apply a random hash to the vertex indices (which will
//! effect a random permutation). We then sort and thereafter maintain
//! indices in sorted order."
//!
//! We implement the permutation as a keyed 4-round Feistel network over
//! the smallest even-bit-width domain covering `range`, with cycle-walking
//! to stay inside `[0, range)`. This gives an exact bijection (no
//! collisions — essential, or two distinct vertices would alias) that is
//! cheaply invertible for debugging and result readback.

/// Bijective keyed permutation on `[0, range)`.
#[derive(Clone, Debug)]
pub struct IndexHasher {
    range: u64,
    half_bits: u32,
    half_mask: u64,
    keys: [u64; 4],
}

impl IndexHasher {
    pub fn new(range: u64, seed: u64) -> Self {
        assert!(range >= 1, "empty index range");
        // domain = smallest power of 4 >= range (so both Feistel halves
        // have equal width)
        let bits = 64 - (range - 1).leading_zeros().max(0);
        let half_bits = bits.div_ceil(2).max(1);
        let mut sm = crate::util::SplitMix64::new(seed ^ 0xC0FF_EE00_D15E_A5E5);
        let keys = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { range, half_bits, half_mask: (1u64 << half_bits) - 1, keys }
    }

    /// The permutation every PageRank driver applies before edge
    /// partitioning (run seed salted so the permutation decorrelates
    /// from the partition RNG). The lockstep/threaded drivers, the
    /// multi-process workers, and the `sar shard` writer MUST all use
    /// this constructor — a divergent permutation silently breaks the
    /// cross-mode checksum equality the test suite relies on.
    pub fn pagerank(vertices: u64, run_seed: u64) -> IndexHasher {
        IndexHasher::new(vertices, run_seed ^ 0x5EED)
    }

    #[inline]
    fn round(&self, x: u64, key: u64) -> u64 {
        // xorshift-multiply round function, truncated to half width
        let mut z = x.wrapping_add(key);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) & self.half_mask
    }

    #[inline]
    fn feistel(&self, v: u64) -> u64 {
        let mut l = v >> self.half_bits;
        let mut r = v & self.half_mask;
        for &k in &self.keys {
            let nl = r;
            let nr = l ^ self.round(r, k);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }

    #[inline]
    fn feistel_inv(&self, v: u64) -> u64 {
        let mut l = v >> self.half_bits;
        let mut r = v & self.half_mask;
        for &k in self.keys.iter().rev() {
            let nr = l;
            let nl = r ^ self.round(l, k);
            l = nl;
            r = nr;
        }
        (l << self.half_bits) | r
    }

    /// Permute an index (cycle-walk until back inside the range).
    #[inline]
    pub fn hash(&self, idx: i64) -> i64 {
        debug_assert!(idx >= 0 && (idx as u64) < self.range);
        let mut v = idx as u64;
        loop {
            v = self.feistel(v);
            if v < self.range {
                return v as i64;
            }
        }
    }

    /// Invert the permutation.
    #[inline]
    pub fn unhash(&self, idx: i64) -> i64 {
        debug_assert!(idx >= 0 && (idx as u64) < self.range);
        let mut v = idx as u64;
        loop {
            v = self.feistel_inv(v);
            if v < self.range {
                return v as i64;
            }
        }
    }

    pub fn range(&self) -> u64 {
        self.range
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_bijection_small() {
        for range in [1u64, 2, 7, 64, 100, 257] {
            let h = IndexHasher::new(range, 42);
            let mut seen = vec![false; range as usize];
            for i in 0..range {
                let y = h.hash(i as i64) as usize;
                assert!(y < range as usize);
                assert!(!seen[y], "collision at {i} -> {y} (range {range})");
                seen[y] = true;
            }
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let h = IndexHasher::new(1_000_003, 7);
        for i in (0..1_000_003).step_by(971) {
            assert_eq!(h.unhash(h.hash(i)), i);
        }
    }

    #[test]
    fn seeds_give_different_permutations() {
        let a = IndexHasher::new(10_000, 1);
        let b = IndexHasher::new(10_000, 2);
        let same = (0..1000).filter(|&i| a.hash(i) == b.hash(i)).count();
        assert!(same < 10, "permutations too similar: {same}");
    }

    #[test]
    fn spreads_clustered_indices() {
        // consecutive hot indices should land far apart: check that the
        // hashes of 0..100 do NOT occupy a narrow band.
        let h = IndexHasher::new(1_000_000, 3);
        let hashes: Vec<i64> = (0..100).map(|i| h.hash(i)).collect();
        let min = *hashes.iter().min().unwrap();
        let max = *hashes.iter().max().unwrap();
        assert!(max - min > 500_000, "permutation did not spread indices");
    }

    #[test]
    fn uniformity_across_halves() {
        let h = IndexHasher::new(100_000, 11);
        let lower = (0..10_000).filter(|&i| h.hash(i) < 50_000).count();
        assert!((lower as i64 - 5_000).abs() < 500, "lower-half count {lower}");
    }
}
