//! Machine-readable bench trajectory emission (`BENCH_<n>.json`).
//!
//! Every `sar tune` run records its fitted cost-model constants and the
//! full ranked schedule sweep — predicted *and* measured times, with
//! p10/p50/p90 spread — as one JSON document, so the repo accumulates a
//! perf trajectory that CI can assert on and graph across PRs.

use super::{Calibration, ScheduleEval, TuneOpts, TuneOutcome};
use crate::bench::{json_f64, json_str, summary_json};
use crate::simnet::CostModel;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

fn cost_model_json(m: &CostModel) -> String {
    format!(
        "{{\"setup_secs\":{},\"bandwidth_bps\":{},\"outlier_prob\":{},\
         \"outlier_mean_secs\":{},\"packet_floor_bytes\":{}}}",
        json_f64(m.setup_secs),
        json_f64(m.bandwidth_bps),
        json_f64(m.outlier_prob),
        json_f64(m.outlier_mean_secs),
        json_f64(m.floor_bytes(0.6))
    )
}

fn calibration_json(c: &Calibration) -> String {
    let samples = c
        .samples
        .iter()
        .map(|s| format!("{{\"bytes\":{},\"secs\":{}}}", s.bytes, summary_json(&s.secs)))
        .collect::<Vec<_>>()
        .join(",");
    let fitted = match &c.fitted {
        Some(m) => cost_model_json(m),
        None => "null".to_string(),
    };
    format!(
        "{{\"transport\":{},\"fitted\":{},\"samples\":[{samples}]}}",
        json_str(&c.transport),
        fitted
    )
}

fn degrees_json(degrees: &[usize]) -> String {
    let inner = degrees.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(",");
    format!("[{inner}]")
}

fn schedule_json(e: &ScheduleEval, chosen: bool) -> String {
    let payloads =
        e.layer_payloads.iter().map(|p| json_f64(*p)).collect::<Vec<_>>().join(",");
    let compressions =
        e.compressions.iter().map(|c| json_f64(*c)).collect::<Vec<_>>().join(",");
    format!(
        "{{\"rank\":{},\"degrees\":{},\"predicted_secs\":{},\"measured_secs\":{},\
         \"layer_payload_bytes\":[{payloads}],\"compression\":[{compressions}],\
         \"chosen\":{chosen}}}",
        e.rank,
        degrees_json(&e.degrees),
        json_f64(e.predicted_secs),
        summary_json(&e.measured)
    )
}

/// Render the whole outcome as one JSON document.
pub fn bench_json(opts: &TuneOpts, outcome: &TuneOutcome) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": 3,");
    let _ = writeln!(out, "  \"tool\": \"sar tune\",");
    let _ = writeln!(out, "  \"world\": {},", outcome.profile.world);
    let _ = writeln!(
        out,
        "  \"dataset\": {{\"name\":{},\"scale\":{},\"seed\":{}}},",
        json_str(&outcome.profile.dataset),
        json_f64(opts.scale),
        opts.seed
    );
    let _ = writeln!(
        out,
        "  \"bench_opts\": {{\"warmup_iters\":{},\"measure_iters\":{},\"fast\":{}}},",
        opts.bench.warmup_iters, opts.bench.measure_iters, opts.fast
    );
    let cals =
        outcome.calibrations.iter().map(calibration_json).collect::<Vec<_>>().join(",\n    ");
    let _ = writeln!(out, "  \"calibration\": [\n    {cals}\n  ],");
    let _ = writeln!(out, "  \"model_source\": {},", json_str(&outcome.model_source));
    let _ = writeln!(out, "  \"model\": {},", cost_model_json(&outcome.model));
    let curve = outcome
        .degree_compression
        .iter()
        .map(|(k, c)| format!("{{\"degree\":{k},\"compression\":{}}}", json_f64(*c)))
        .collect::<Vec<_>>()
        .join(",");
    let _ = writeln!(out, "  \"compression_by_degree\": [{curve}],");
    let scheds = outcome
        .evals
        .iter()
        .map(|e| schedule_json(e, e.degrees == outcome.profile.degrees))
        .collect::<Vec<_>>()
        .join(",\n    ");
    let _ = writeln!(out, "  \"schedules\": [\n    {scheds}\n  ],");
    let _ = writeln!(
        out,
        "  \"chosen\": {{\"degrees\":{},\"profile\":{},\"profile_digest\":\"{:016x}\"}}",
        degrees_json(&outcome.profile.degrees),
        json_str(&opts.out.display().to_string()),
        outcome.profile.digest()
    );
    out.push_str("}\n");
    out
}

/// Write the bench document, creating parent directories.
pub fn write_bench_json(path: &Path, opts: &TuneOpts, outcome: &TuneOutcome) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, bench_json(opts, outcome))
        .with_context(|| format!("writing bench trajectory {}", path.display()))?;
    log::info!("wrote bench trajectory {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::profile::{TuneProfile, TUNE_FORMAT};
    use crate::util::Summary;

    fn tiny_outcome() -> TuneOutcome {
        let model = CostModel::fit(&[(1024, 1e-4), (1 << 20, 1e-3)]).unwrap();
        let mk = |degrees: Vec<usize>, rank: usize| ScheduleEval {
            degrees,
            predicted_secs: 1e-3 * rank as f64,
            measured: Summary::of(&[1e-3, 2e-3, 3e-3]),
            layer_payloads: vec![1000.0, 600.0],
            compressions: vec![0.6],
            rank,
        };
        TuneOutcome {
            profile: TuneProfile {
                format: TUNE_FORMAT,
                world: 4,
                degrees: vec![2, 2],
                cost: model,
                packet_floor: model.floor_bytes(0.6),
                compression: vec![0.6],
                dataset: "twitter".into(),
                scale: 0.01,
                seed: 42,
            },
            calibrations: vec![Calibration {
                transport: "mem".into(),
                samples: vec![],
                fitted: None,
            }],
            model,
            model_source: "tcp-loopback".into(),
            evals: vec![mk(vec![2, 2], 1), mk(vec![4], 2), mk(vec![4, 1], 3)],
            degree_compression: vec![(2, 0.6), (4, 0.55)],
        }
    }

    /// The emitted document must be structurally sound JSON (balanced
    /// braces/brackets outside strings, no trailing commas before
    /// closers) and carry the required fields.
    #[test]
    fn bench_json_is_balanced_and_complete() {
        let opts = TuneOpts::default();
        let doc = bench_json(&opts, &tiny_outcome());
        for key in [
            "\"bench\": 3",
            "\"model\":",
            "\"setup_secs\"",
            "\"bandwidth_bps\"",
            "\"schedules\":",
            "\"predicted_secs\"",
            "\"measured_secs\"",
            "\"chosen\":",
            "\"fitted\":null",
        ] {
            assert!(doc.contains(key), "missing {key} in:\n{doc}");
        }
        assert!(doc.matches("\"rank\":").count() >= 3, "need >= 3 schedule rows");
        let mut depth = 0i64;
        let mut in_str = false;
        let mut prev = ' ';
        for c in doc.chars() {
            if in_str {
                if c == '"' && prev != '\\' {
                    in_str = false;
                }
            } else {
                match c {
                    '"' => in_str = true,
                    '{' | '[' => depth += 1,
                    '}' | ']' => depth -= 1,
                    _ => {}
                }
                assert!(depth >= 0, "unbalanced close in:\n{doc}");
            }
            prev = c;
        }
        assert_eq!(depth, 0, "unbalanced JSON:\n{doc}");
        assert!(!doc.contains(",\n  ]") && !doc.contains(",}"), "trailing comma:\n{doc}");
    }
}
