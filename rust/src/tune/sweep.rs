//! The degree-schedule sweep (paper Figure 6, measurement-driven).
//!
//! Every candidate schedule runs the *real* protocol once on the real
//! dataset via the lockstep driver: the recorded message trace yields
//! (a) a wall-clock measurement, (b) the per-layer payloads and their
//! index-collision compression factors, and (c) a cluster-scale time
//! prediction by replaying the trace through the discrete-event
//! simulator under the fitted cost model. Ranking by predicted time
//! reproduces the paper's methodology: laptop traces + calibrated
//! model → cluster ranking.

use super::{TuneData, TuneOpts};
use crate::allreduce::Phase;
use crate::apps::pagerank::DistPageRank;
use crate::bench::bench;
use crate::simnet::{simulate_collective, CostModel, SimParams};
use crate::topology::factorizations_bounded;
use crate::util::Summary;
use anyhow::Result;

/// One candidate schedule's measurements and prediction.
#[derive(Clone, Debug)]
pub struct ScheduleEval {
    pub degrees: Vec<usize>,
    /// Simulator wall-clock for one reduce trace under the fitted model.
    pub predicted_secs: f64,
    /// Measured wall-clock of one full iteration (SpMV + allreduce) —
    /// identical compute across schedules, so differences are
    /// topological.
    pub measured: Summary,
    /// Per-node payload entering each reduce-down layer, bytes.
    pub layer_payloads: Vec<f64>,
    /// Measured per-layer compression factors (see
    /// [`layer_compressions`]); one entry per layer with degree ≥ 2.
    pub compressions: Vec<f64>,
    /// 1-based position after ranking (1 = chosen).
    pub rank: usize,
}

/// Candidate schedules for a world of `m`: all ordered factorizations
/// (capped), padded for tiny worlds with degree-1 probe variants
/// (`[m, 1]`, `[1, m]`) so a sweep always carries at least three rows —
/// a degree-1 layer exchanges nothing, so these measure the protocol's
/// pure layer-barrier overhead at zero payload.
pub fn candidate_schedules(m: usize, cap: usize) -> Vec<Vec<usize>> {
    let mut out = factorizations_bounded(m, cap.max(1));
    if m >= 2 {
        for probe in [vec![m, 1], vec![1, m]] {
            if out.len() >= 3 {
                break;
            }
            out.push(probe);
        }
    }
    out
}

/// Evaluate one schedule: run config + one traced reduce on the actual
/// dataset, measure repeat iterations, and simulate the trace under the
/// fitted model.
pub fn eval_schedule(
    data: &TuneData,
    degrees: &[usize],
    model: &CostModel,
    opts: &TuneOpts,
    world: usize,
) -> Result<ScheduleEval> {
    let mut dist = build_dist(data, degrees)?;
    let label = degrees.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("x");
    let measured = bench(&format!("schedule {label}"), &opts.bench, || {
        dist.step();
    });
    let trace = dist.iter_traces.last().expect("bench ran at least one step");
    let sim = simulate_collective(
        trace,
        world,
        &SimParams { cost: *model, threads: opts.threads, merge_bps: 2e9, seed: opts.seed },
    );
    let layer_payloads: Vec<f64> = (0..degrees.len())
        .map(|l| trace.per_node_payload(Phase::ReduceDown, l, world, degrees[l]))
        .collect();
    let compressions = layer_compressions(trace, degrees, &layer_payloads);
    Ok(ScheduleEval {
        degrees: degrees.to_vec(),
        predicted_secs: sim.total_secs,
        measured: measured.secs,
        layer_payloads,
        compressions,
        rank: 0,
    })
}

fn build_dist(data: &TuneData, degrees: &[usize]) -> Result<DistPageRank> {
    // One shared partition for the whole sweep (see [`TuneData`]); only
    // the butterfly is rebuilt per schedule. The CSR clone is a flat
    // memcpy — no regeneration or re-partitioning.
    DistPageRank::from_shards(
        data.shards.clone(),
        data.vertices,
        degrees.to_vec(),
        data.hasher.clone(),
    )
}

/// Per-layer compression factors from a reduce trace. For layer ℓ with
/// a successor carrying data, the factor is the ratio of successive
/// per-node payloads (the planner's `bytes ← bytes · c` constant). For
/// the deepest exchanging layer the reduce-up echo is used instead: the
/// up phase ships the *merged* values over the same edges the down
/// phase shipped raw parts, so `up/down` bytes approximate the merge's
/// collision compression. Degree-1 layers exchange nothing and are
/// skipped. Factors are clamped to (0, 1] — merged data never exceeds
/// its parts under a sum reduction.
pub fn layer_compressions(
    trace: &crate::allreduce::Trace,
    degrees: &[usize],
    payloads: &[f64],
) -> Vec<f64> {
    let exchanging: Vec<usize> = (0..degrees.len()).filter(|&l| degrees[l] >= 2).collect();
    let mut out = Vec::with_capacity(exchanging.len());
    for (pos, &l) in exchanging.iter().enumerate() {
        let c = match exchanging.get(pos + 1) {
            Some(&next) if payloads[l] > 0.0 => payloads[next] / payloads[l],
            _ => {
                let down = trace.layer_bytes(Phase::ReduceDown, l) as f64;
                let up = trace.layer_bytes(Phase::ReduceUp, l) as f64;
                if down > 0.0 && up > 0.0 {
                    up / down
                } else {
                    1.0
                }
            }
        };
        out.push(c.clamp(f64::MIN_POSITIVE, 1.0));
    }
    out
}

/// Measured compression after a k-way merge, per distinct first-layer
/// degree across the sweep — the planner's data constant as a curve
/// (higher degrees merge more streams and compress harder on power-law
/// data).
pub fn compression_by_degree(evals: &[ScheduleEval]) -> Vec<(usize, f64)> {
    let mut out: Vec<(usize, f64)> = Vec::new();
    for e in evals {
        // compressions[i] belongs to the i-th *exchanging* (degree ≥ 2)
        // layer, so pair through that mapping rather than raw zip.
        let first_exchanging = e.degrees.iter().position(|&k| k >= 2);
        if let (Some(l0), Some(&c)) = (first_exchanging, e.compressions.first()) {
            let k = e.degrees[l0];
            if !out.iter().any(|&(kk, _)| kk == k) {
                out.push((k, c));
            }
        }
    }
    out.sort_unstable_by_key(|&(k, _)| k);
    out
}

/// Geometric mean of every measured layer compression across the sweep
/// (fallback planner constant when the chosen schedule has a single
/// layer and therefore no payload ratio of its own).
pub fn aggregate_compression(evals: &[ScheduleEval]) -> f64 {
    let all: Vec<f64> =
        evals.iter().flat_map(|e| e.compressions.iter().copied()).filter(|c| *c > 0.0).collect();
    if all.is_empty() {
        return 0.7; // the paper's power-law ballpark
    }
    let log_mean = all.iter().map(|c| c.ln()).sum::<f64>() / all.len() as f64;
    log_mean.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allreduce::Trace;

    #[test]
    fn candidates_cover_world_and_pad_small_sweeps() {
        let c4 = candidate_schedules(4, 64);
        assert!(c4.len() >= 3, "small worlds must pad to >= 3 rows: {c4:?}");
        for d in &c4 {
            assert_eq!(d.iter().product::<usize>(), 4, "{d:?}");
        }
        assert!(c4.contains(&vec![4]) && c4.contains(&vec![2, 2]));
        // Larger worlds need no padding.
        let c8 = candidate_schedules(8, 64);
        assert_eq!(c8.len(), 4);
        assert!(!c8.iter().any(|d| d.contains(&1)));
        // The cap still floors at 3 via padding only when needed.
        let capped = candidate_schedules(64, 2);
        assert_eq!(capped.len(), 3);
    }

    #[test]
    fn compressions_come_from_payload_ratios_and_up_echo() {
        // Two-layer degree-2 trace over 4 nodes: layer 0 payload 100,
        // layer 1 payload 60 (c0 = 0.6); layer 1 up echo is half its
        // down bytes (c1 = 0.5).
        let mut t = Trace::new();
        for (src, dst) in [(0usize, 1usize), (1, 0), (2, 3), (3, 2)] {
            t.record(Phase::ReduceDown, 0, src, dst, 50);
        }
        for (src, dst) in [(0usize, 2usize), (2, 0), (1, 3), (3, 1)] {
            t.record(Phase::ReduceDown, 1, src, dst, 30);
            t.record(Phase::ReduceUp, 1, dst, src, 15);
        }
        let degrees = [2usize, 2];
        let payloads: Vec<f64> = (0..2)
            .map(|l| t.per_node_payload(Phase::ReduceDown, l, 4, degrees[l]))
            .collect();
        assert!((payloads[0] - 100.0).abs() < 1e-9);
        assert!((payloads[1] - 60.0).abs() < 1e-9);
        let cs = layer_compressions(&t, &degrees, &payloads);
        assert_eq!(cs.len(), 2);
        assert!((cs[0] - 0.6).abs() < 1e-9, "{cs:?}");
        assert!((cs[1] - 0.5).abs() < 1e-9, "{cs:?}");
        // Degree-1 probe layers are skipped entirely.
        let cs = layer_compressions(&t, &[2, 1], &payloads);
        assert_eq!(cs.len(), 1);
    }

    #[test]
    fn aggregate_compression_is_geometric_mean() {
        let mk = |cs: Vec<f64>| ScheduleEval {
            degrees: vec![2, 2],
            predicted_secs: 0.0,
            measured: Summary::of(&[]),
            layer_payloads: vec![],
            compressions: cs,
            rank: 0,
        };
        let evals = vec![mk(vec![0.25]), mk(vec![1.0])];
        assert!((aggregate_compression(&evals) - 0.5).abs() < 1e-12);
        assert_eq!(aggregate_compression(&[mk(vec![])]), 0.7);
    }
}
