//! Measurement-driven topology autotuner (`sar tune`).
//!
//! The paper's central result is that the optimal Sparse Allreduce
//! network is a nested butterfly of *heterogeneous* degree, and that the
//! optimum depends on two families of constants the rest of the repo
//! only hard-codes as 2013-EC2 defaults: machine constants (per-message
//! setup cost and the packet floor it induces — `simnet::CostModel`) and
//! data constants (the per-layer index-collision compression of the
//! actual dataset — `topology::PlannerParams::compression`). This module
//! measures both on the machine and data at hand and sweeps the degree
//! schedules against them:
//!
//! 1. **Calibration** ([`calibrate`]): microbenchmark the real
//!    transports (in-process channels, TCP loopback) across message
//!    sizes and least-squares fit `time = setup + bytes/bandwidth`
//!    ([`CostModel::fit`]).
//! 2. **Data profiling + sweep** ([`sweep`]): run one real allreduce per
//!    candidate degree schedule on the actual dataset (synthetic preset
//!    or `sar shard` directory), extract per-layer compression factors
//!    from the recorded [`crate::allreduce::Trace`], and rank the
//!    schedules by replaying each trace through
//!    [`crate::simnet::simulate_collective`] under the fitted model
//!    (paper Figure 6), with wall-clock measurements alongside.
//! 3. **Persistence** ([`profile`]): the winning schedule plus the
//!    fitted constants become a digest-protected `tune.toml`
//!    ([`TuneProfile`]) that `sar launch` / `sar pagerank` consume via
//!    `RunConfig`'s `[tune] profile` key (or `--tune-profile`), flowing
//!    the tuned schedule into the `WorkerPlan` all workers execute.
//! 4. **Trajectory** ([`report`]): every run emits a machine-readable
//!    `BENCH_<n>.json` (fitted constants, ranked schedules with
//!    predicted and measured times) so the repo records a perf
//!    trajectory across PRs.

pub mod calibrate;
pub mod profile;
pub mod report;
pub mod sweep;

pub use calibrate::{calibrate_mem, calibrate_tcp_loopback, CalSample, Calibration};
pub use profile::TuneProfile;
pub use sweep::{candidate_schedules, ScheduleEval};

use crate::apps::pagerank::PageRankShards;
use crate::bench::BenchOpts;
use crate::graph::{load_all_shards, Csr, DatasetPreset, DatasetSpec};
use crate::partition::IndexHasher;
use crate::simnet::CostModel;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Everything `sar tune` needs for one tuning run.
#[derive(Clone, Debug)]
pub struct TuneOpts {
    /// Dataset preset key (twitter | yahoo | docterm).
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    /// Machines to plan for (ignored with `shards`: the shard count
    /// fixes the world).
    pub world: usize,
    /// Tune against a `sar shard` directory instead of a preset.
    pub shards: Option<PathBuf>,
    /// Where the digest-protected tuning profile is written.
    pub out: PathBuf,
    /// Where the machine-readable bench trajectory row is written.
    pub bench_json: PathBuf,
    /// Warmup/measure iteration counts (`--warmup` / `--iters`).
    pub bench: BenchOpts,
    /// Sender threads assumed by the simulator (Figure 7 knob).
    pub threads: usize,
    /// Trim calibration sizes and iterations for CI smoke runs.
    pub fast: bool,
    /// Cap on enumerated candidate schedules.
    pub max_schedules: usize,
}

impl Default for TuneOpts {
    fn default() -> Self {
        Self {
            dataset: "twitter".to_string(),
            scale: 0.01,
            seed: 42,
            world: 4,
            shards: None,
            out: PathBuf::from("tune.toml"),
            bench_json: PathBuf::from("BENCH_3.json"),
            bench: BenchOpts::default(),
            threads: 8,
            fast: false,
            max_schedules: 64,
        }
    }
}

/// The dataset a tuning run profiles against, partitioned exactly once
/// (the hash partition depends only on the world size, not on the
/// schedule): every candidate schedule sees the identical shard CSRs,
/// so measured differences are purely topological — and a sweep of N
/// schedules pays the O(edges) partitioning cost once, not N times.
pub struct TuneData {
    pub shards: Vec<Csr>,
    pub vertices: i64,
    pub hasher: IndexHasher,
    /// Dataset identity (preset key or the shard manifest's source).
    pub source: String,
}

impl TuneData {
    /// Logical machine count the schedules must cover.
    pub fn world(&self) -> usize {
        self.shards.len()
    }
}

/// Outcome of a tuning run (everything the report serializes).
pub struct TuneOutcome {
    pub profile: TuneProfile,
    pub calibrations: Vec<Calibration>,
    /// The model the sweep ranked under (best fitted, else the 2013-EC2
    /// fallback).
    pub model: CostModel,
    pub model_source: String,
    /// Candidate schedules, best (rank 1) first.
    pub evals: Vec<ScheduleEval>,
    /// Measured compression after a k-way merge, per probed first-layer
    /// degree (the planner's data constant as a curve).
    pub degree_compression: Vec<(usize, f64)>,
}

/// Run the full tune pipeline and write `tune.toml` + `BENCH_*.json`.
pub fn run_tune(opts: &TuneOpts) -> Result<TuneOutcome> {
    // --- stage 1: acquire + partition the dataset --------------------
    // Before the (seconds-long) calibration so an invalid world or a
    // bad shard directory fails fast.
    let data = load_tune_data(opts)?;
    let world = data.world();
    if world < 2 {
        bail!("tuning needs a world of at least 2 machines, got {world}");
    }

    // --- stage 2: transport calibration ------------------------------
    let sizes: &[usize] = if opts.fast {
        &[4 << 10, 64 << 10, 512 << 10]
    } else {
        &[4 << 10, 32 << 10, 128 << 10, 512 << 10, 2 << 20, 4 << 20]
    };
    log::info!("calibrating transports over {} message sizes", sizes.len());
    let cal_mem = calibrate_mem(sizes, &opts.bench);
    // A sandbox that denies loopback sockets must degrade down the
    // fallback ladder (mem fit → ec2-2013), not abort the tune run.
    let cal_tcp = match calibrate_tcp_loopback(sizes, &opts.bench) {
        Ok(c) => c,
        Err(e) => {
            log::warn!("tcp loopback calibration unavailable ({e:#}); using mem fit only");
            Calibration { transport: "tcp-loopback".to_string(), samples: Vec::new(), fitted: None }
        }
    };
    let (model, model_source) = match (&cal_tcp.fitted, &cal_mem.fitted) {
        (Some(m), _) => (*m, "tcp-loopback".to_string()),
        (None, Some(m)) => (*m, "mem".to_string()),
        (None, None) => {
            log::warn!("calibration could not fit a model; keeping the 2013-EC2 constants");
            (CostModel::ec2_2013(), "ec2-2013-fallback".to_string())
        }
    };
    log::info!(
        "fitted model ({model_source}): setup {:.1} µs, bandwidth {:.1} MB/s, floor {:.0} bytes",
        model.setup_secs * 1e6,
        model.bandwidth_bps / 1e6,
        model.floor_bytes(0.6)
    );

    // --- stage 3: sweep schedules ------------------------------------
    let candidates = candidate_schedules(world, opts.max_schedules);
    log::info!("sweeping {} candidate schedules over world {world}", candidates.len());
    let mut evals = Vec::with_capacity(candidates.len());
    for degrees in candidates {
        let eval = sweep::eval_schedule(&data, &degrees, &model, opts, world)
            .with_context(|| format!("evaluating schedule {degrees:?}"))?;
        evals.push(eval);
    }
    // Rank by model-predicted time (the paper's Figure 6 axis);
    // wall-clock medians break ties.
    evals.sort_by(|a, b| {
        (a.predicted_secs, a.measured.p50)
            .partial_cmp(&(b.predicted_secs, b.measured.p50))
            .expect("finite times")
    });
    for (i, e) in evals.iter_mut().enumerate() {
        e.rank = i + 1;
    }

    // --- stage 4: compression curve + profile ------------------------
    let degree_compression = sweep::compression_by_degree(&evals);
    // Degree-1 padded probes (tiny-world sweeps) measure barrier
    // overhead for the report but are never *chosen*: a no-op layer in
    // the persisted schedule would only add handshake rounds.
    let best = evals.iter().find(|e| !e.degrees.contains(&1)).unwrap_or(&evals[0]);
    let profile = TuneProfile {
        format: profile::TUNE_FORMAT,
        world,
        degrees: best.degrees.clone(),
        cost: model,
        transport: model_source.clone(),
        packet_floor: model.floor_bytes(0.6),
        compression: if best.compressions.is_empty() {
            vec![sweep::aggregate_compression(&evals)]
        } else {
            best.compressions.clone()
        },
        dataset: data.source.clone(),
        scale: opts.scale,
        seed: opts.seed,
    };
    if let Some(parent) = opts.out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    profile.save(&opts.out)?;
    log::info!("wrote tuning profile {} (digest {:016x})", opts.out.display(), profile.digest());

    let outcome = TuneOutcome {
        profile,
        calibrations: vec![cal_mem, cal_tcp],
        model,
        model_source,
        evals,
        degree_compression,
    };
    report::write_bench_json(&opts.bench_json, opts, &outcome)?;
    Ok(outcome)
}

fn load_tune_data(opts: &TuneOpts) -> Result<TuneData> {
    if let Some(dir) = &opts.shards {
        let (manifest, shards) = load_all_shards(dir)
            .with_context(|| format!("loading shard set from {}", dir.display()))?;
        let hasher = IndexHasher::pagerank(manifest.vertices as u64, manifest.seed);
        log::info!(
            "profiling against {} shards of {} ({} vertices)",
            shards.len(),
            manifest.source,
            manifest.vertices
        );
        return Ok(TuneData {
            shards,
            vertices: manifest.vertices,
            hasher,
            source: manifest.source.clone(),
        });
    }
    if opts.world < 2 {
        bail!("tuning needs a world of at least 2 machines, got {}", opts.world);
    }
    let preset = DatasetPreset::by_name(&opts.dataset)
        .with_context(|| format!("unknown dataset `{}` (twitter|yahoo|docterm)", opts.dataset))?;
    let spec = DatasetSpec::new(preset, opts.scale, opts.seed);
    log::info!("generating {} (scale {})", spec.name(), opts.scale);
    let graph = spec.generate();
    // Partition ONCE for the whole sweep — the hash partition depends
    // only on (world, seed), never on the schedule.
    let built = PageRankShards::build(&graph, opts.world, opts.seed);
    Ok(TuneData {
        shards: built.shards,
        vertices: built.vertices,
        hasher: built.hasher,
        source: opts.dataset.clone(),
    })
}

/// Load a tuning profile and apply it to a run configuration: the tuned
/// degree schedule and fitted cost model replace the config's, and the
/// result is re-validated against any pinned worker count. This is the
/// single consumption path for `--tune-profile` and the `[tune] profile`
/// config key, used by `sar launch` and `sar pagerank` alike — so the
/// tuned schedule flows into `LaunchOpts`, the `WorkerPlan`, and the
/// lockstep oracle identically.
pub fn apply_profile(cfg: &mut crate::config::RunConfig, path: &Path) -> Result<TuneProfile> {
    let prof = TuneProfile::load(path)
        .with_context(|| format!("loading tuning profile {}", path.display()))?;
    prof.apply(cfg)?;
    Ok(prof)
}

/// [`apply_profile`] plus a transport-compatibility gate for consumers
/// that know what wire their pool runs on (`"tcp"` for multi-process
/// pools, `"mem"` for in-process modes). A mem-calibrated profile's
/// constants are effectively memcpy throughput — its packet floor is
/// orders of magnitude below a TCP pool's, so the schedule it blesses
/// is wrong for the real wire and the profile is rejected rather than
/// silently applied.
pub fn apply_profile_checked(
    cfg: &mut crate::config::RunConfig,
    path: &Path,
    pool_transport: &str,
) -> Result<TuneProfile> {
    let prof = TuneProfile::load(path)
        .with_context(|| format!("loading tuning profile {}", path.display()))?;
    check_profile_transport(&prof, pool_transport)?;
    prof.apply(cfg)?;
    Ok(prof)
}

/// Reject or warn when a profile's calibration transport disagrees with
/// the transport the consuming pool runs (`pool_transport`: `"tcp"` |
/// `"mem"`). Hard mismatches (mem constants driving a TCP pool) are
/// errors; soft ones (unrecorded transport on legacy profiles, the
/// ec2-2013 fallback, or pessimistic TCP constants applied in-process)
/// only warn.
pub fn check_profile_transport(prof: &TuneProfile, pool_transport: &str) -> Result<()> {
    match (prof.transport.as_str(), pool_transport) {
        ("mem", "tcp") => bail!(
            "tuning profile was calibrated on the in-process `mem` transport but this \
             pool runs TCP: its packet floor ({:.0} bytes) reflects memcpy, not the \
             wire — re-run `sar tune` on a machine with loopback sockets available",
            prof.packet_floor
        ),
        ("tcp-loopback", "tcp") | ("mem", "mem") => Ok(()),
        ("", _) => {
            log::warn!(
                "tuning profile records no calibration transport (written before the \
                 field existed); cannot verify it matches this {pool_transport} pool"
            );
            Ok(())
        }
        (other, _) => {
            log::warn!(
                "tuning profile calibrated on `{other}` applied to a {pool_transport} \
                 pool; constants may not reflect this wire"
            );
            Ok(())
        }
    }
}
