//! Transport calibration: fit the cost model from measurements.
//!
//! An echo peer (node 1) returns every message to node 0; node 0 times
//! the round trip per message size and halves it into a one-way
//! estimate. Least-squares over the per-size medians
//! ([`CostModel::fit`]) yields the machine's actual `setup` and
//! `bandwidth` constants — and therefore its packet floor
//! ([`CostModel::floor_bytes`]) — replacing the hard-coded 2013-EC2
//! numbers everywhere a `CostModel` is consumed (the discrete-event
//! simulator, the degree planner, delay-injected transports).

use crate::allreduce::Phase;
use crate::bench::BenchOpts;
use crate::simnet::CostModel;
use crate::transport::{Envelope, MemTransport, Tag, TcpNet, Transport};
use crate::util::{human_duration, Summary};
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One message size's timing distribution (one-way seconds).
#[derive(Clone, Debug)]
pub struct CalSample {
    pub bytes: usize,
    pub secs: Summary,
}

/// A calibrated transport: raw samples plus the fitted model (`None`
/// when the samples could not support a fit — see [`CostModel::fit`]).
#[derive(Clone, Debug)]
pub struct Calibration {
    pub transport: String,
    pub samples: Vec<CalSample>,
    pub fitted: Option<CostModel>,
}

/// Sequence number that tells the echo peer to exit.
const STOP_SEQ: u32 = u32::MAX;

/// Generous bound on a single echo; a loopback message taking longer
/// means the transport is wedged and calibration should give up.
const ECHO_TIMEOUT: Duration = Duration::from_secs(10);

/// Calibrate the in-process channel transport (upper bound on what any
/// wire can do on this machine; the fitted "bandwidth" is effectively
/// memcpy throughput).
pub fn calibrate_mem(sizes: &[usize], opts: &BenchOpts) -> Calibration {
    let t = Arc::new(MemTransport::new(2));
    echo_calibrate(t, "mem", sizes, opts)
}

/// Calibrate real TCP sockets over loopback — the transport
/// multi-process runs on a single host actually use.
pub fn calibrate_tcp_loopback(sizes: &[usize], opts: &BenchOpts) -> Result<Calibration> {
    let t = TcpNet::local(2).context("binding loopback calibration sockets")?;
    Ok(echo_calibrate(t, "tcp-loopback", sizes, opts))
}

fn echo_calibrate<T: Transport + 'static>(
    t: Arc<T>,
    name: &str,
    sizes: &[usize],
    opts: &BenchOpts,
) -> Calibration {
    let peer = {
        let t = t.clone();
        std::thread::spawn(move || loop {
            match t.recv(1, ECHO_TIMEOUT) {
                Ok(env) => {
                    if env.tag.seq == STOP_SEQ {
                        return;
                    }
                    let reply = Envelope { src: 1, tag: env.tag, payload: env.payload };
                    if t.send(0, reply).is_err() {
                        return;
                    }
                }
                Err(_) => return,
            }
        })
    };

    let mut samples = Vec::with_capacity(sizes.len());
    let mut seq = 0u32;
    let roundtrip = |bytes: usize, seq: u32| -> bool {
        let env =
            Envelope { src: 0, tag: Tag::new(seq, Phase::ReduceDown, 0), payload: vec![0u8; bytes] };
        if t.send(1, env).is_err() {
            return false;
        }
        t.recv(0, ECHO_TIMEOUT).is_ok()
    };
    'sizes: for &bytes in sizes {
        for _ in 0..opts.warmup_iters {
            seq += 1;
            if !roundtrip(bytes, seq) {
                log::warn!("{name} calibration: echo failed at {bytes} bytes (warmup)");
                break 'sizes;
            }
        }
        let mut xs = Vec::with_capacity(opts.measure_iters);
        for _ in 0..opts.measure_iters {
            seq += 1;
            let t0 = Instant::now();
            if !roundtrip(bytes, seq) {
                log::warn!("{name} calibration: echo failed at {bytes} bytes");
                break 'sizes;
            }
            // Half the round trip ≈ one-way wire time.
            xs.push(t0.elapsed().as_secs_f64() / 2.0);
        }
        let secs = Summary::of(&xs);
        log::info!(
            "  calib {name} {bytes:>8} B: p10 {} p50 {} p90 {} (n={})",
            human_duration(secs.p10),
            human_duration(secs.p50),
            human_duration(secs.p90),
            secs.n
        );
        samples.push(CalSample { bytes, secs });
    }
    // Release the echo peer (ignore failures: it also exits on timeout).
    let _ = t.send(
        1,
        Envelope { src: 0, tag: Tag::new(STOP_SEQ, Phase::ReduceDown, 0), payload: Vec::new() },
    );
    let _ = peer.join();

    let points: Vec<(usize, f64)> = samples.iter().map(|s| (s.bytes, s.secs.p50)).collect();
    let fitted = CostModel::fit(&points);
    Calibration { transport: name.to_string(), samples, fitted }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_calibration_produces_samples() {
        let opts = BenchOpts { warmup_iters: 1, measure_iters: 3 };
        let cal = calibrate_mem(&[1 << 10, 64 << 10, 1 << 20], &opts);
        assert_eq!(cal.transport, "mem");
        assert_eq!(cal.samples.len(), 3);
        for s in &cal.samples {
            assert_eq!(s.secs.n, 3);
            assert!(s.secs.p50 >= 0.0);
        }
        // A fit may legitimately fail on a fast machine (timer noise),
        // but when it succeeds it must be physical.
        if let Some(m) = cal.fitted {
            assert!(m.setup_secs > 0.0 && m.bandwidth_bps > 0.0);
        }
    }

    #[test]
    fn tcp_loopback_calibration_fits_a_model() {
        let opts = BenchOpts { warmup_iters: 1, measure_iters: 5 };
        let cal = calibrate_tcp_loopback(&[4 << 10, 256 << 10, 2 << 20], &opts).unwrap();
        assert_eq!(cal.samples.len(), 3, "all sizes must calibrate");
        // Larger messages must not be faster in the medians by a wide
        // margin (sanity on the harness, not the machine).
        let first = cal.samples.first().unwrap().secs.p50;
        let last = cal.samples.last().unwrap().secs.p50;
        assert!(last > first * 0.5, "2 MB ({last}s) vs 4 KB ({first}s)");
        if let Some(m) = cal.fitted {
            assert!(m.bandwidth_bps > 1e6, "loopback slower than 1 MB/s is a harness bug");
            assert!(m.setup_secs < 1.0);
        }
    }
}
