//! Fault tolerance via data replication and packet racing (paper §V).
//!
//! With replication factor `r`, the butterfly runs over `L` *logical*
//! nodes, each hosted by `r` physical machines: logical `i` lives on
//! physical `i, i+L, …, i+(r−1)·L`. Every config/reduce message addressed
//! to logical `j` is fanned out to all of `j`'s replicas, and a receiver
//! expecting a message from logical `j` accepts the **first** copy that
//! arrives from any replica (remaining copies are discarded — "packet
//! racing", which also turns latency-outlier straggling into a race the
//! fastest path wins).
//!
//! The protocol completes unless *every* replica of some logical node is
//! dead; with `r = 2` and random failures that takes ≈ √M failures
//! (birthday paradox), verified empirically by [`expected_failures_to_kill`].

pub mod heartbeat;
pub mod replicated;

pub use heartbeat::{ClockAlign, FailureDetector, Health};
pub use replicated::{run_replicated_cluster, ReplicaMap, ReplicatedHandle};

use crate::util::Pcg32;

/// Monte-Carlo estimate of how many uniformly-random machine failures it
/// takes before some logical node loses all `r` replicas, on `logical`
/// logical nodes (physical machines = `logical * r`).
pub fn expected_failures_to_kill(logical: usize, r: usize, trials: usize, seed: u64) -> f64 {
    let mut rng = Pcg32::new(seed);
    let mut total = 0usize;
    for _ in 0..trials {
        let m = logical * r;
        let mut dead = vec![0usize; logical];
        let mut order: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut order);
        for (count, &phys) in order.iter().enumerate() {
            let l = phys % logical;
            dead[l] += 1;
            if dead[l] == r {
                total += count + 1;
                break;
            }
        }
    }
    total as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tolerance_scales_like_sqrt_m_for_r2() {
        // Paper §V-A: with r=2, ~√M random failures kill a replica group.
        for &logical in &[16usize, 64, 256] {
            let est = expected_failures_to_kill(logical, 2, 400, 7);
            let sqrt_m = ((logical * 2) as f64).sqrt();
            assert!(
                est > 0.8 * sqrt_m && est < 3.0 * sqrt_m,
                "logical={logical}: est {est:.1} vs sqrt(M) {sqrt_m:.1}"
            );
        }
    }

    #[test]
    fn no_replication_dies_immediately() {
        let est = expected_failures_to_kill(64, 1, 200, 9);
        assert_eq!(est, 1.0);
    }

    #[test]
    fn higher_replication_tolerates_more() {
        let r2 = expected_failures_to_kill(32, 2, 300, 11);
        let r3 = expected_failures_to_kill(32, 3, 300, 11);
        assert!(r3 > r2, "r=3 ({r3:.1}) should beat r=2 ({r2:.1})");
    }
}
