//! Heartbeat-based failure detection for the deployment plane.
//!
//! The paper's fault model (§V) is fail-stop machines masked by data
//! replication and packet racing. In-process drivers observe failure as
//! a transport timeout; across OS processes the control plane needs an
//! explicit detector: every worker heartbeats its control connection,
//! and the coordinator combines *liveness timeouts* (no beat within the
//! window) with *hard evidence* (control-connection EOF when the process
//! dies). Only hard evidence drives irreversible decisions — staleness
//! can reverse when a stalled worker resumes beating.
//! [`FailureDetector::group_extinct_hard`] answers the question
//! replication poses: has some logical node lost every replica, i.e.
//! must the run be aborted instead of left to hang, or can the
//! collective still complete via failover?

use super::ReplicaMap;
use crate::obs;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Graded worker health (VR-style `HealthDetector`, not binary
/// dead/alive). `Normal` workers are in full standing; `Suspect`
/// workers are deprioritized but still participate (their results are
/// not awaited first); `Unhealthy` workers trigger handoff of any
/// in-flight work to surviving replicas. Ordered so `max()` over
/// signals yields the worst grade.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Health {
    Normal,
    Suspect,
    Unhealthy,
}

impl std::fmt::Display for Health {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Health::Normal => "normal",
            Health::Suspect => "suspect",
            Health::Unhealthy => "unhealthy",
        })
    }
}

struct WorkerState {
    last_beat: Instant,
    dead: bool,
    /// Externally-fed soft signal: the nonce'd RTT readout flagged this
    /// worker as a straggler. Reversible, like staleness.
    straggler: bool,
    /// Consecutive readouts that flagged this worker (reset to 0 when a
    /// readout names someone else or nobody). The elastic re-planner
    /// penalizes only *consistent* stragglers, so one slow heartbeat
    /// never re-shapes the pool.
    straggler_streak: u32,
}

/// Tracks per-worker liveness from heartbeats and connection EOFs.
pub struct FailureDetector {
    timeout: Duration,
    workers: Mutex<Vec<WorkerState>>,
}

impl FailureDetector {
    /// All workers start alive with a fresh beat.
    pub fn new(workers: usize, timeout: Duration) -> Self {
        let now = Instant::now();
        Self {
            timeout,
            workers: Mutex::new(
                (0..workers)
                    .map(|_| WorkerState {
                        last_beat: now,
                        dead: false,
                        straggler: false,
                        straggler_streak: 0,
                    })
                    .collect(),
            ),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.lock().expect("detector poisoned").len()
    }

    /// Record a heartbeat (any control-plane traffic counts).
    pub fn beat(&self, worker: usize) {
        let mut w = self.workers.lock().expect("detector poisoned");
        w[worker].last_beat = Instant::now();
    }

    /// Record hard evidence of death (control connection EOF/error).
    pub fn mark_dead(&self, worker: usize) {
        let mut w = self.workers.lock().expect("detector poisoned");
        if !w[worker].dead {
            // Count the transition, not every piece of corroborating
            // evidence — a dead worker's EOF and its failed sends must
            // not inflate the census.
            obs::global().counter("fault.hard_dead").inc();
        }
        w[worker].dead = true;
    }

    /// Dead by evidence, or silent past the heartbeat window.
    pub fn is_dead(&self, worker: usize) -> bool {
        let w = self.workers.lock().expect("detector poisoned");
        w[worker].dead || w[worker].last_beat.elapsed() > self.timeout
    }

    /// Dead by hard evidence only (EOF / reported failure) — never by
    /// heartbeat staleness. Staleness is *reversible* (a stalled worker
    /// may resume beating), so irreversible control-plane decisions
    /// (skipping START, aborting a run) must use this instead of
    /// [`FailureDetector::is_dead`].
    pub fn is_hard_dead(&self, worker: usize) -> bool {
        let w = self.workers.lock().expect("detector poisoned");
        w[worker].dead
    }

    /// All hard-dead workers (see [`FailureDetector::is_hard_dead`]).
    pub fn hard_dead(&self) -> Vec<usize> {
        let w = self.workers.lock().expect("detector poisoned");
        w.iter().enumerate().filter(|(_, s)| s.dead).map(|(i, _)| i).collect()
    }

    /// Feed the nonce'd RTT straggler readout: `straggler` is the one
    /// worker (if any) whose heartbeat RTT is an outlier. The flag is a
    /// soft, reversible signal — it can only raise a worker to Suspect,
    /// never to Unhealthy — and each call replaces the previous verdict.
    pub fn set_straggler(&self, straggler: Option<usize>) {
        let mut w = self.workers.lock().expect("detector poisoned");
        for (i, s) in w.iter_mut().enumerate() {
            let was = s.straggler;
            s.straggler = straggler == Some(i);
            // Edge-triggered counters (the feed is periodic — counting
            // every readout would just measure the feed rate).
            if s.straggler && !was {
                obs::global().counter("fault.suspect_raised").inc();
            } else if was && !s.straggler {
                obs::global().counter("fault.suspect_cleared").inc();
            }
            if s.straggler {
                s.straggler_streak = s.straggler_streak.saturating_add(1);
            } else {
                s.straggler_streak = 0;
            }
        }
    }

    /// Consecutive-straggler streaks, index-aligned with workers. Feeds
    /// the elastic re-planner's consistently-slow penalty.
    pub fn streaks(&self) -> Vec<u32> {
        let w = self.workers.lock().expect("detector poisoned");
        w.iter().map(|s| s.straggler_streak).collect()
    }

    /// Graded health verdict for one worker. `Unhealthy` = hard
    /// evidence or silence past the full heartbeat window (the old
    /// binary "dead"); `Suspect` = staleness past half the window, or
    /// the RTT straggler flag; `Normal` otherwise. Suspect is
    /// reversible by construction — a beat or a clean RTT restores
    /// Normal — while Unhealthy-by-evidence is sticky.
    pub fn grade(&self, worker: usize) -> Health {
        let w = self.workers.lock().expect("detector poisoned");
        Self::grade_state(&w[worker], self.timeout)
    }

    /// Graded health for every worker, index-aligned.
    pub fn grades(&self) -> Vec<Health> {
        let w = self.workers.lock().expect("detector poisoned");
        w.iter().map(|s| Self::grade_state(s, self.timeout)).collect()
    }

    fn grade_state(s: &WorkerState, timeout: Duration) -> Health {
        let stale = s.last_beat.elapsed();
        if s.dead || stale > timeout {
            Health::Unhealthy
        } else if s.straggler || stale > timeout / 2 {
            Health::Suspect
        } else {
            Health::Normal
        }
    }

    pub fn dead(&self) -> Vec<usize> {
        let w = self.workers.lock().expect("detector poisoned");
        w.iter()
            .enumerate()
            .filter(|(_, s)| s.dead || s.last_beat.elapsed() > self.timeout)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn alive(&self) -> Vec<usize> {
        let dead = self.dead();
        (0..self.workers()).filter(|i| !dead.contains(i)).collect()
    }

    /// Whether logical node `logical` has lost *every* replica to
    /// hard-evidence death — the §V condition under which the protocol
    /// cannot complete for that node. This is the check the cluster
    /// coordinator's collect phase uses to abort (for nodes still
    /// missing a report) instead of hanging.
    pub fn group_extinct_hard(&self, map: &ReplicaMap, logical: usize) -> bool {
        map.replicas(logical).all(|p| self.is_hard_dead(p))
    }

    /// Whether the collective can still complete under `map`: every
    /// logical node must retain at least one live replica (paper §V —
    /// the protocol fails only when a whole replica group dies). Uses
    /// the timeout-inclusive [`FailureDetector::is_dead`] view; returns
    /// the first extinct logical node on failure.
    pub fn check_quorum(&self, map: &ReplicaMap) -> Result<(), usize> {
        let dead = self.dead();
        for logical in 0..map.logical {
            if map.replicas(logical).all(|p| dead.contains(&p)) {
                return Err(logical);
            }
        }
        Ok(())
    }
}

/// Per-worker clock alignment for the trace pull, drift-checked across
/// pulls. Each TRACE reply yields a midpoint offset estimate
/// ([`crate::obs::trace::estimate_offset_us`]) whose error is bounded
/// by half the request round trip; this tracker keeps the
/// tightest-uncertainty estimate per worker and flags *drift* — a fresh
/// estimate disagreeing with the kept one by more than their combined
/// uncertainty plus a drift allowance — which on a fail-stop cluster
/// means a worker's clock is slewing and its merged timeline should be
/// read with that much slack. The uncertainty fed in is the same
/// nonce'd heartbeat RTT the straggler readout uses, so no extra
/// measurement traffic exists just for tracing.
pub struct ClockAlign {
    /// Per worker: best (offset_us, uncertainty_us) seen so far.
    offsets: Vec<Option<(i64, u64)>>,
}

impl ClockAlign {
    pub fn new(workers: usize) -> Self {
        Self { offsets: vec![None; workers] }
    }

    /// Fold one fresh estimate in. `uncertainty_us` is half the round
    /// trip that bracketed the estimate (RTT/2). Returns the drift in
    /// µs if the fresh estimate disagrees with the kept one beyond
    /// their combined uncertainty (+ [`Self::DRIFT_SLACK_US`] for
    /// timer-resolution noise); the kept estimate still updates when
    /// the fresh one is tighter, so a genuinely slewing clock keeps
    /// being tracked rather than pinned to a stale offset.
    pub fn update(&mut self, worker: usize, offset_us: i64, uncertainty_us: u64) -> Option<i64> {
        let fresh = (offset_us, uncertainty_us);
        let drift = match self.offsets[worker] {
            Some((kept_off, kept_unc)) => {
                let gap = (offset_us - kept_off).abs();
                let budget = kept_unc
                    .saturating_add(uncertainty_us)
                    .saturating_add(Self::DRIFT_SLACK_US);
                (gap as u64 > budget).then_some(offset_us - kept_off)
            }
            None => None,
        };
        match self.offsets[worker] {
            // Keep the tighter estimate — unless drift fired, in which
            // case the newest reading is the truth going forward.
            Some((_, kept_unc)) if drift.is_none() && kept_unc <= uncertainty_us => {}
            _ => self.offsets[worker] = Some(fresh),
        }
        drift
    }

    /// Allowance for scheduling/timer noise on top of the RTT bound.
    pub const DRIFT_SLACK_US: u64 = 1_000;

    /// The kept offset for `worker` (µs; worker timestamps map onto the
    /// coordinator timebase as `ts − offset`).
    pub fn offset_us(&self, worker: usize) -> Option<i64> {
        self.offsets.get(worker).copied().flatten().map(|(o, _)| o)
    }

    /// The kept uncertainty for `worker` (µs).
    pub fn uncertainty_us(&self, worker: usize) -> Option<u64> {
        self.offsets.get(worker).copied().flatten().map(|(_, u)| u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beats_keep_workers_alive() {
        let d = FailureDetector::new(3, Duration::from_millis(80));
        std::thread::sleep(Duration::from_millis(50));
        d.beat(0);
        d.beat(2);
        std::thread::sleep(Duration::from_millis(50));
        // 1 never beat after construction → stale; 0 and 2 fresh
        assert!(!d.is_dead(0));
        assert!(d.is_dead(1));
        assert!(!d.is_dead(2));
        assert_eq!(d.dead(), vec![1]);
        assert_eq!(d.alive(), vec![0, 2]);
    }

    #[test]
    fn eof_evidence_is_immediate() {
        let d = FailureDetector::new(2, Duration::from_secs(60));
        assert!(!d.is_dead(1));
        d.mark_dead(1);
        assert!(d.is_dead(1));
        assert_eq!(d.dead(), vec![1]);
    }

    #[test]
    fn quorum_follows_replica_groups() {
        // 2 logical × 2 replicas: logical 0 on {0, 2}, logical 1 on {1, 3}
        let map = ReplicaMap::new(2, 2);
        let d = FailureDetector::new(4, Duration::from_secs(60));
        assert_eq!(d.check_quorum(&map), Ok(()));
        d.mark_dead(0);
        assert_eq!(d.check_quorum(&map), Ok(()), "replica 2 still covers logical 0");
        d.mark_dead(2);
        assert_eq!(d.check_quorum(&map), Err(0), "logical 0 extinct");
    }

    #[test]
    fn group_extinct_needs_hard_evidence() {
        let map = ReplicaMap::new(2, 2);
        // Tiny timeout: both replicas of logical 0 go heartbeat-stale…
        let d = FailureDetector::new(4, Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(10));
        assert!(d.is_dead(0) && d.is_dead(2), "stale by timeout");
        // …but staleness is reversible, so the group is NOT extinct.
        assert!(!d.group_extinct_hard(&map, 0));
        d.mark_dead(0);
        assert!(!d.group_extinct_hard(&map, 0), "one replica still only stale");
        d.mark_dead(2);
        assert!(d.group_extinct_hard(&map, 0));
        assert!(!d.group_extinct_hard(&map, 1));
    }

    /// Satellite: the staleness → recovery path. A worker that goes
    /// heartbeat-stale and then beats again must come back to life, and
    /// staleness alone must never read as hard death — so a late
    /// heartbeat can never be turned into an irreversible failover
    /// decision by the control plane.
    #[test]
    fn late_heartbeat_recovers_a_stale_worker() {
        // Generous window: the revived-worker assertions below re-check
        // elapsed time at call site, so the window must comfortably
        // exceed any plausible CI scheduling stall.
        let d = FailureDetector::new(2, Duration::from_millis(400));
        std::thread::sleep(Duration::from_millis(600));
        // Both stale by timeout…
        assert!(d.is_dead(0) && d.is_dead(1), "workers should be stale");
        // …but neither is hard-dead: staleness is reversible evidence.
        assert!(!d.is_hard_dead(0) && !d.is_hard_dead(1));
        assert!(d.hard_dead().is_empty());
        // The late heartbeat arrives: worker 0 is alive again.
        d.beat(0);
        assert!(!d.is_dead(0), "a late heartbeat must revive a stale worker");
        assert!(d.is_dead(1), "worker 1 is still stale");
        assert_eq!(d.alive(), vec![0]);
        // Even a whole stale replica group is not extinct.
        let map = ReplicaMap::new(1, 2);
        assert!(!d.group_extinct_hard(&map, 0));
    }

    /// Satellite: the hard-evidence path. Control-connection EOF
    /// (mark_dead) is sticky — a heartbeat arriving after it must NOT
    /// resurrect the worker (the failover decision already happened and
    /// must fire exactly once), and repeated evidence for the same
    /// worker collapses into one dead entry, not one failover per EOF.
    #[test]
    fn hard_evidence_is_sticky_and_counted_once() {
        let d = FailureDetector::new(3, Duration::from_secs(60));
        d.mark_dead(1);
        assert!(d.is_hard_dead(1));
        // A racing heartbeat (the beat thread can still be draining)
        // must not undo hard evidence.
        d.beat(1);
        assert!(d.is_hard_dead(1), "a beat after EOF must not resurrect the worker");
        assert!(d.is_dead(1));
        // Duplicate evidence (EOF + FAILED message) is one death, so the
        // coordinator's failover/masking logic triggers exactly once.
        d.mark_dead(1);
        d.mark_dead(1);
        assert_eq!(d.hard_dead(), vec![1]);
        assert_eq!(d.dead(), vec![1]);
        let map = ReplicaMap::new(1, 3);
        assert!(!d.group_extinct_hard(&map, 0), "replicas 0 and 2 still cover");
        d.mark_dead(0);
        d.mark_dead(2);
        assert!(d.group_extinct_hard(&map, 0));
        assert_eq!(d.hard_dead(), vec![0, 1, 2]);
    }

    #[test]
    fn no_replication_quorum_is_every_worker() {
        let map = ReplicaMap::new(4, 1);
        let d = FailureDetector::new(4, Duration::from_secs(60));
        d.mark_dead(3);
        assert_eq!(d.check_quorum(&map), Err(3));
    }

    /// Graded health: staleness walks a worker Normal → Suspect (past
    /// half the window) → Unhealthy (past the full window), a beat walks
    /// it back, and hard evidence pins Unhealthy regardless of beats.
    #[test]
    fn health_grades_follow_staleness_and_evidence() {
        let d = FailureDetector::new(2, Duration::from_millis(400));
        assert_eq!(d.grades(), vec![Health::Normal, Health::Normal]);
        std::thread::sleep(Duration::from_millis(250));
        // Past half the window but under the full one.
        assert_eq!(d.grade(0), Health::Suspect);
        d.beat(0);
        assert_eq!(d.grade(0), Health::Normal, "a beat restores Normal");
        std::thread::sleep(Duration::from_millis(600));
        assert_eq!(d.grade(0), Health::Unhealthy, "silent past the window");
        d.beat(0);
        assert_eq!(d.grade(0), Health::Normal, "staleness is reversible");
        d.mark_dead(1);
        d.beat(1);
        assert_eq!(d.grade(1), Health::Unhealthy, "hard evidence is sticky");
    }

    /// The RTT straggler flag raises exactly one worker to Suspect and
    /// each readout replaces the last — a worker that stops straggling
    /// (or a `None` readout) drops back to Normal. The flag never
    /// escalates past Suspect on its own.
    #[test]
    fn rtt_straggler_is_suspect_and_reversible() {
        let d = FailureDetector::new(3, Duration::from_secs(60));
        d.set_straggler(Some(1));
        assert_eq!(d.grades(), vec![Health::Normal, Health::Suspect, Health::Normal]);
        assert!(!d.is_dead(1), "suspect is not dead");
        d.set_straggler(Some(2));
        assert_eq!(d.grades(), vec![Health::Normal, Health::Normal, Health::Suspect]);
        d.set_straggler(None);
        assert_eq!(d.grades(), vec![Health::Normal; 3]);
        // Ordering supports worst-of aggregation.
        assert!(Health::Normal < Health::Suspect && Health::Suspect < Health::Unhealthy);
    }

    /// Satellite: clock-offset tracking across trace pulls. Known
    /// injected offsets are recovered within RTT/2 (the estimator's
    /// bound, tested end to end in `obs::trace`); here the drift check:
    /// agreeing estimates never flag, the tighter uncertainty wins, and
    /// an estimate outside the combined uncertainty reports its drift.
    #[test]
    fn clock_align_keeps_tight_estimates_and_flags_drift() {
        let mut a = ClockAlign::new(2);
        assert_eq!(a.offset_us(0), None);
        // First estimate is kept verbatim.
        assert_eq!(a.update(0, 10_000, 2_000), None);
        assert_eq!(a.offset_us(0), Some(10_000));
        assert_eq!(a.uncertainty_us(0), Some(2_000));
        // A compatible, tighter estimate replaces it.
        assert_eq!(a.update(0, 10_500, 400), None);
        assert_eq!(a.offset_us(0), Some(10_500));
        assert_eq!(a.uncertainty_us(0), Some(400));
        // A compatible but looser estimate does not.
        assert_eq!(a.update(0, 10_300, 3_000), None);
        assert_eq!(a.offset_us(0), Some(10_500));
        // An estimate outside combined uncertainty + slack is drift —
        // reported, and adopted as the new truth.
        let drift = a.update(0, 20_000, 400).expect("drift must be flagged");
        assert_eq!(drift, 20_000 - 10_500);
        assert_eq!(a.offset_us(0), Some(20_000));
        // Worker 1 is independent.
        assert_eq!(a.update(1, -5_000, 100), None);
        assert_eq!(a.offset_us(1), Some(-5_000));
        assert_eq!(a.offset_us(0), Some(20_000));
    }

    /// Streaks count *consecutive* flags only: repeated readouts naming
    /// the same worker accumulate, and any readout naming someone else
    /// (or nobody) resets the count — so the re-planner's
    /// consistently-slow penalty cannot fire off scattered one-offs.
    #[test]
    fn straggler_streaks_accumulate_and_reset() {
        let d = FailureDetector::new(3, Duration::from_secs(60));
        assert_eq!(d.streaks(), vec![0, 0, 0]);
        d.set_straggler(Some(1));
        d.set_straggler(Some(1));
        d.set_straggler(Some(1));
        assert_eq!(d.streaks(), vec![0, 3, 0]);
        // A readout naming a different worker resets 1 and starts 2.
        d.set_straggler(Some(2));
        assert_eq!(d.streaks(), vec![0, 0, 1]);
        // A clean readout resets everyone.
        d.set_straggler(None);
        assert_eq!(d.streaks(), vec![0, 0, 0]);
    }
}
