//! Replicated cluster driver: the threaded driver with message fan-out
//! and first-wins racing (paper §V).

use crate::allreduce::protocol::{ConfigPart, NodeProtocol, Phase};
use crate::sparse::{IndexSet, ReduceOp};
use crate::topology::{Butterfly, NodeId};
use crate::transport::{wire, Envelope, SenderPool, Tag, Transport, TransportError};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Mapping between logical protocol nodes and physical machines.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaMap {
    pub logical: usize,
    pub r: usize,
}

impl ReplicaMap {
    pub fn new(logical: usize, r: usize) -> Self {
        assert!(logical >= 1 && r >= 1);
        Self { logical, r }
    }

    pub fn physical(&self) -> usize {
        self.logical * self.r
    }

    /// Physical machines hosting logical node `l`.
    pub fn replicas(&self, l: usize) -> impl Iterator<Item = usize> + '_ {
        let logical = self.logical;
        (0..self.r).map(move |rho| l + rho * logical)
    }

    /// Logical node hosted by physical machine `p`.
    pub fn logical_of(&self, p: usize) -> usize {
        p % self.logical
    }

    /// Replica ordinal of physical machine `p`.
    pub fn replica_of(&self, p: usize) -> usize {
        p / self.logical
    }
}

/// A physical machine's endpoint in a replicated cluster. It executes the
/// protocol of its *logical* node; messages fan out to all replicas of the
/// destination and receives race across all replicas of the source.
pub struct ReplicatedHandle<T: Transport> {
    proto: NodeProtocol,
    map: ReplicaMap,
    /// This machine's physical id (inbox address).
    phys: NodeId,
    transport: Arc<T>,
    pool: SenderPool,
    /// First-wins buffer: (tag, logical src) → payload. Duplicate replica
    /// copies are dropped on arrival.
    pending: HashMap<(Tag, usize), Vec<u8>>,
    /// Tags already consumed, to discard late replica duplicates.
    consumed: HashMap<(Tag, usize), ()>,
    seq: u32,
    timeout: Duration,
}

impl<T: Transport + 'static> ReplicatedHandle<T> {
    pub fn new(
        topo: Butterfly,
        map: ReplicaMap,
        phys: NodeId,
        transport: Arc<T>,
        send_threads: usize,
    ) -> Self {
        assert_eq!(topo.machines(), map.logical, "topology runs over logical nodes");
        assert!(phys < map.physical());
        let logical = map.logical_of(phys);
        Self {
            proto: NodeProtocol::new(topo, logical),
            map,
            phys,
            transport,
            pool: SenderPool::new(send_threads),
            pending: HashMap::new(),
            consumed: HashMap::new(),
            seq: 0,
            timeout: Duration::from_secs(30),
        }
    }

    pub fn physical(&self) -> NodeId {
        self.phys
    }

    pub fn logical(&self) -> NodeId {
        self.proto.node()
    }

    pub fn protocol(&self) -> &NodeProtocol {
        &self.proto
    }

    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Offset the collective sequence space (e.g. by `job_id << 16`) so
    /// tags from consecutive jobs on one long-lived transport can never
    /// collide — see `NodeHandle::set_seq_base`.
    pub fn set_seq_base(&mut self, base: u32) {
        self.seq = base;
    }

    /// Wait for the first copy of `(tag, logical src)` from any replica.
    fn await_race(&mut self, tag: Tag, lsrc: usize) -> Result<Vec<u8>, TransportError> {
        if let Some(p) = self.pending.remove(&(tag, lsrc)) {
            self.consumed.insert((tag, lsrc), ());
            return Ok(p);
        }
        loop {
            let env = self.transport.recv(self.phys, self.timeout)?;
            let got_lsrc = self.map.logical_of(env.src);
            let key = (env.tag, got_lsrc);
            if self.consumed.contains_key(&key) || self.pending.contains_key(&key) {
                continue; // late duplicate from a slower replica: discard
            }
            if env.tag == tag && got_lsrc == lsrc {
                self.consumed.insert(key, ());
                return Ok(env.payload);
            }
            self.pending.insert(key, env.payload);
        }
    }

    /// Group exchange with fan-out to every replica of each destination.
    fn exchange(
        &mut self,
        phase: Phase,
        layer: usize,
        outgoing: Vec<Vec<u8>>,
        own: Vec<u8>,
    ) -> Result<Vec<Vec<u8>>, TransportError> {
        let tag = Tag::new(self.seq, phase, layer);
        let group = self.proto.group(layer); // logical ids
        let my_slot = self.proto.slot(layer);
        for (j, payload) in outgoing.into_iter().enumerate() {
            if j == my_slot {
                continue;
            }
            for pdst in self.map.replicas(group[j]) {
                let env = Envelope { src: self.phys, tag, payload: payload.clone() };
                self.pool.send(&self.transport, pdst, env);
            }
        }
        let mut got: Vec<Vec<u8>> = vec![Vec::new(); group.len()];
        for (j, &lsrc) in group.iter().enumerate() {
            if j == my_slot {
                got[j] = own.clone();
            } else {
                got[j] = self.await_race(tag, lsrc)?;
            }
        }
        // Note: unlike the non-replicated driver we neither propagate send
        // errors (a dead replica must not fail the protocol) nor BARRIER
        // on our own sends: the duplicate copy racing to each receiver
        // already covers a slow/outlier send, so waiting for the slow copy
        // would re-import exactly the tail latency replication is meant to
        // mask (paper §V-B "packets racing"). In-flight sends drain in the
        // pool's worker threads; tags keep later layers unambiguous.
        Ok(got)
    }

    /// Run the config phase (replica-consistent: all replicas of a logical
    /// node must pass identical outbound/inbound sets).
    pub fn config(
        &mut self,
        outbound: IndexSet,
        inbound: IndexSet,
    ) -> Result<(), TransportError> {
        self.seq += 1;
        self.consumed.clear();
        self.proto.begin_config(outbound, inbound);
        for layer in 0..self.proto.topology().layers() {
            let parts = self.proto.config_outgoing(layer);
            let my_slot = self.proto.slot(layer);
            let own = wire::encode_config_part(&parts[my_slot]);
            let outgoing: Vec<Vec<u8>> = parts.iter().map(wire::encode_config_part).collect();
            let got = self.exchange(Phase::ConfigDown, layer, outgoing, own)?;
            let decoded: Vec<ConfigPart> = got
                .iter()
                .map(|b| wire::decode_config_part(b))
                .collect::<std::io::Result<_>>()
                .map_err(TransportError::Io)?;
            self.proto.config_absorb(layer, &decoded);
        }
        Ok(())
    }

    /// The scatter-reduce sweep down the butterfly.
    fn reduce_down<R: ReduceOp>(&mut self, values: Vec<R::T>) -> Result<Vec<R::T>, TransportError> {
        let layers = self.proto.topology().layers();
        let mut current = values;
        for layer in 0..layers {
            let segs = self.proto.reduce_down_outgoing::<R>(layer, &current);
            let my_slot = self.proto.slot(layer);
            let own = wire::encode_values::<R>(segs[my_slot]);
            let outgoing: Vec<Vec<u8>> = segs.iter().map(|s| wire::encode_values::<R>(s)).collect();
            let got = self.exchange(Phase::ReduceDown, layer, outgoing, own)?;
            let decoded: Vec<Vec<R::T>> = got
                .iter()
                .map(|b| wire::decode_values::<R>(b))
                .collect::<std::io::Result<_>>()
                .map_err(TransportError::Io)?;
            let refs: Vec<&[R::T]> = decoded.iter().map(|v| v.as_slice()).collect();
            current = self.proto.reduce_down_absorb::<R>(layer, &refs);
        }
        Ok(current)
    }

    /// The allgather sweep back up the butterfly.
    fn reduce_up<R: ReduceOp>(&mut self, values: Vec<R::T>) -> Result<Vec<R::T>, TransportError> {
        let layers = self.proto.topology().layers();
        let mut current = values;
        for layer in (0..layers).rev() {
            let segs = self.proto.reduce_up_outgoing::<R>(layer, &current);
            let my_slot = self.proto.slot(layer);
            let own = wire::encode_values::<R>(&segs[my_slot]);
            let outgoing: Vec<Vec<u8>> = segs.iter().map(|s| wire::encode_values::<R>(s)).collect();
            let got = self.exchange(Phase::ReduceUp, layer, outgoing, own)?;
            let decoded: Vec<Vec<R::T>> = got
                .iter()
                .map(|b| wire::decode_values::<R>(b))
                .collect::<std::io::Result<_>>()
                .map_err(TransportError::Io)?;
            current = self.proto.reduce_up_absorb::<R>(layer, &decoded);
        }
        Ok(current)
    }

    /// Run one reduce.
    pub fn reduce<R: ReduceOp>(&mut self, values: Vec<R::T>) -> Result<Vec<R::T>, TransportError> {
        self.seq += 1;
        let bottom = self.reduce_down::<R>(values)?;
        let projected = self.proto.apply_final_map::<R>(&bottom);
        self.reduce_up::<R>(projected)
    }

    /// The scatter-reduce half of one collective, mirroring
    /// [`crate::allreduce::NodeHandle::reduce_down_half`] for the remote
    /// collective plane: advances the sequence, runs the down sweep, and
    /// returns this logical node's fully-reduced bottom range (aligned
    /// with `protocol().bottom_down_set()`). The handle is left
    /// mid-collective — the caller MUST follow with
    /// [`ReplicatedHandle::reduce_up_half`].
    pub fn reduce_down_half<R: ReduceOp>(
        &mut self,
        values: Vec<R::T>,
    ) -> Result<Vec<R::T>, TransportError> {
        self.seq += 1;
        self.reduce_down::<R>(values)
    }

    /// The allgather half completing a
    /// [`ReplicatedHandle::reduce_down_half`]: `values` must hold one
    /// entry per `protocol().bottom_up_set()` index; returns values
    /// aligned with the inbound set. Does NOT advance the sequence —
    /// both halves belong to one collective.
    pub fn reduce_up_half<R: ReduceOp>(
        &mut self,
        values: Vec<R::T>,
    ) -> Result<Vec<R::T>, TransportError> {
        self.reduce_up::<R>(values)
    }
}

/// Spawn worker threads for every *alive* physical machine (machines in
/// `dead` never start — simulating fail-stop before the collective) and
/// collect per-physical-machine results (`None` for dead machines).
pub fn run_replicated_cluster<T, F, O>(
    topo: &Butterfly,
    map: ReplicaMap,
    transport: Arc<T>,
    send_threads: usize,
    dead: &[NodeId],
    worker: F,
) -> Vec<Option<O>>
where
    T: Transport + 'static,
    O: Send + 'static,
    F: Fn(ReplicatedHandle<T>) -> O + Send + Sync + 'static,
{
    assert_eq!(transport.machines(), map.physical());
    let worker = Arc::new(worker);
    let mut handles: Vec<Option<std::thread::JoinHandle<O>>> = Vec::new();
    for phys in 0..map.physical() {
        if dead.contains(&phys) {
            handles.push(None);
            continue;
        }
        let topo = topo.clone();
        let transport = transport.clone();
        let worker = worker.clone();
        handles.push(Some(std::thread::spawn(move || {
            let h = ReplicatedHandle::new(topo, map, phys, transport, send_threads);
            worker(h)
        })));
    }
    handles
        .into_iter()
        .map(|h| h.map(|h| h.join().expect("replica worker panicked")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allreduce::LocalCluster;
    use crate::sparse::SumF32;
    use crate::transport::MemTransport;
    use crate::util::Pcg32;

    fn random_inputs(
        m: usize,
        range: i64,
        seed: u64,
    ) -> (Vec<(Vec<i64>, Vec<f32>)>, Vec<Vec<i64>>) {
        let mut rng = Pcg32::new(seed);
        let outs = (0..m)
            .map(|_| {
                let k = rng.gen_range(1, 50);
                let mut idx: Vec<i64> = rng
                    .sample_distinct(range as usize, k)
                    .into_iter()
                    .map(|x| x as i64)
                    .collect();
                idx.sort_unstable();
                let val: Vec<f32> = idx.iter().map(|_| rng.next_f32()).collect();
                (idx, val)
            })
            .collect();
        let ins = (0..m)
            .map(|_| {
                let k = rng.gen_range(1, 30);
                let mut idx: Vec<i64> = rng
                    .sample_distinct(range as usize, k)
                    .into_iter()
                    .map(|x| x as i64)
                    .collect();
                idx.sort_unstable();
                idx
            })
            .collect();
        (outs, ins)
    }

    fn reference(topo: &Butterfly, outs: &[(Vec<i64>, Vec<f32>)], ins: &[Vec<i64>]) -> Vec<Vec<f32>> {
        let mut local = LocalCluster::new(topo.clone());
        local.config(
            outs.iter().map(|(i, _)| IndexSet::from_sorted(i.clone())).collect(),
            ins.iter().map(|i| IndexSet::from_sorted(i.clone())).collect(),
        );
        local.reduce::<SumF32>(outs.iter().map(|(_, v)| v.clone()).collect()).0
    }

    fn run_with_dead(topo: Butterfly, r: usize, dead: Vec<usize>, seed: u64) {
        let logical = topo.machines();
        let map = ReplicaMap::new(logical, r);
        let (outs, ins) = random_inputs(logical, topo.index_range(), seed);
        let want = reference(&topo, &outs, &ins);
        let transport = Arc::new(MemTransport::new(map.physical()));
        let outs = Arc::new(outs);
        let ins = Arc::new(ins);
        let (o2, i2) = (outs.clone(), ins.clone());
        let results = run_replicated_cluster(
            &topo,
            map,
            transport,
            4,
            &dead,
            move |mut h: ReplicatedHandle<MemTransport>| {
                let l = h.logical();
                h.config(
                    IndexSet::from_sorted(o2[l].0.clone()),
                    IndexSet::from_sorted(i2[l].clone()),
                )
                .unwrap();
                h.reduce::<SumF32>(o2[l].1.clone()).unwrap()
            },
        );
        // every alive machine must hold its logical node's correct result
        let mut checked = 0;
        for (phys, res) in results.iter().enumerate() {
            if let Some(got) = res {
                let l = map.logical_of(phys);
                assert_eq!(got.len(), want[l].len());
                for (g, w) in got.iter().zip(&want[l]) {
                    assert!((g - w).abs() < 1e-4, "phys {phys} logical {l}");
                }
                checked += 1;
            }
        }
        assert_eq!(checked, map.physical() - dead.len());
    }

    #[test]
    fn replicated_no_failures_matches_reference() {
        run_with_dead(Butterfly::new(vec![2, 2], 256), 2, vec![], 31);
    }

    #[test]
    fn survives_one_dead_node() {
        // kill physical 5 (replica 1 of logical 1 in a 4-logical r=2 map)
        run_with_dead(Butterfly::new(vec![2, 2], 256), 2, vec![5], 32);
    }

    /// The replicated generic serve engine drives the two halves
    /// separately (for `allreduce_with_bottom`); down-half + final map
    /// + up-half must equal one `reduce()` even with a dead replica.
    #[test]
    fn split_halves_match_whole_reduce_with_a_dead_replica() {
        let topo = Butterfly::new(vec![2, 2], 256);
        let logical = topo.machines();
        let map = ReplicaMap::new(logical, 2);
        let (outs, ins) = random_inputs(logical, topo.index_range(), 36);
        let want = reference(&topo, &outs, &ins);
        let transport = Arc::new(MemTransport::new(map.physical()));
        let outs = Arc::new(outs);
        let ins = Arc::new(ins);
        let (o2, i2) = (outs.clone(), ins.clone());
        let results = run_replicated_cluster(
            &topo,
            map,
            transport,
            4,
            &[6], // replica 1 of logical 2
            move |mut h: ReplicatedHandle<MemTransport>| {
                let l = h.logical();
                h.config(
                    IndexSet::from_sorted(o2[l].0.clone()),
                    IndexSet::from_sorted(i2[l].clone()),
                )
                .unwrap();
                let bottom = h.reduce_down_half::<SumF32>(o2[l].1.clone()).unwrap();
                let projected = h.protocol().apply_final_map::<SumF32>(&bottom);
                h.reduce_up_half::<SumF32>(projected).unwrap()
            },
        );
        for (phys, res) in results.iter().enumerate() {
            if let Some(got) = res {
                let l = map.logical_of(phys);
                assert_eq!(got.len(), want[l].len());
                for (g, w) in got.iter().zip(&want[l]) {
                    assert!((g - w).abs() < 1e-4, "phys {phys} logical {l}");
                }
            }
        }
    }

    #[test]
    fn survives_multiple_dead_nodes_distinct_groups() {
        // 8 logical × 2 replicas = 16 physical; kill 3 machines hosting
        // three different logical nodes.
        run_with_dead(Butterfly::new(vec![4, 2], 512), 2, vec![8, 1, 14], 33);
    }

    #[test]
    fn survives_with_r3_two_dead_same_logical() {
        // r=3: two replicas of the same logical node may die.
        run_with_dead(Butterfly::new(vec![2, 2], 128), 3, vec![4, 8], 34);
    }

    #[test]
    fn replica_map_arithmetic() {
        let map = ReplicaMap::new(8, 2);
        assert_eq!(map.physical(), 16);
        assert_eq!(map.replicas(3).collect::<Vec<_>>(), vec![3, 11]);
        assert_eq!(map.logical_of(11), 3);
        assert_eq!(map.replica_of(11), 1);
    }

    #[test]
    fn all_replicas_dead_times_out() {
        // Killing both replicas of logical 0 must stall the others, which
        // then observe a Timeout instead of wrong results.
        let topo = Butterfly::new(vec![2], 64);
        let map = ReplicaMap::new(2, 2);
        let transport = Arc::new(MemTransport::new(4));
        let (outs, ins) = random_inputs(2, 64, 35);
        let outs = Arc::new(outs);
        let ins = Arc::new(ins);
        let (o2, i2) = (outs.clone(), ins.clone());
        let results = run_replicated_cluster(
            &topo,
            map,
            transport,
            2,
            &[0, 2], // both replicas of logical 0
            move |mut h: ReplicatedHandle<MemTransport>| {
                h.set_timeout(Duration::from_millis(300));
                let l = h.logical();
                h.config(
                    IndexSet::from_sorted(o2[l].0.clone()),
                    IndexSet::from_sorted(i2[l].clone()),
                )
            },
        );
        for (phys, res) in results.iter().enumerate() {
            if let Some(r) = res {
                assert!(
                    matches!(r, Err(TransportError::Timeout(_))),
                    "phys {phys}: expected timeout, got {r:?}"
                );
            }
        }
    }
}
