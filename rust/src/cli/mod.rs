//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `sar <subcommand> [--flag value]... [--switch]...`
//! Flags may also be written `--flag=value`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// Positional arguments are only accepted by `help` (topic name);
    /// everywhere else they indicate a typo and error out.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positionals = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                // Both help spellings dispatch to the help command and
                // take a topic positional.
                if subcommand == "help" || subcommand == "--help" {
                    positionals.push(arg);
                    continue;
                }
                bail!("unexpected positional argument `{arg}`");
            };
            if name.is_empty() {
                bail!("empty flag name");
            }
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args { subcommand, flags, switches, positionals })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Reject flags/switches outside `allowed`, pointing at the
    /// subcommand's usage instead of bailing with no guidance.
    pub fn expect_known(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        let unknown = self
            .flags
            .keys()
            .map(|k| k.as_str())
            .chain(self.switches.iter().map(|s| s.as_str()))
            .find(|name| !allowed.contains(name));
        if let Some(name) = unknown {
            bail!(
                "unknown flag `--{name}` for `{cmd}`\n\n{}",
                usage_for(cmd).unwrap_or(USAGE)
            );
        }
        Ok(())
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    /// Parse `--degrees 16x4` (or `16,4`) into a degree schedule.
    pub fn degrees_flag(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flag(name) {
            None => Ok(default.to_vec()),
            Some(v) => parse_degrees(v),
        }
    }
}

/// Parse a degree schedule like `16x4`, `8x4x2` or `16,4`.
pub fn parse_degrees(s: &str) -> Result<Vec<usize>> {
    let parts: Vec<&str> = s.split(['x', ',', 'X']).collect();
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        let k: usize = p.trim().parse().map_err(|_| anyhow::anyhow!("bad degree `{p}` in `{s}`"))?;
        if k == 0 {
            bail!("degree 0 in `{s}`");
        }
        out.push(k);
    }
    if out.is_empty() {
        bail!("empty degree schedule");
    }
    Ok(out)
}

/// Top-level usage text.
pub const USAGE: &str = "\
sparse-allreduce (sar) — Sparse Allreduce for power-law data (Zhao & Canny 2013)

USAGE: sar <command> [flags]

COMMANDS:
  info          show build/runtime info (PJRT platform, artifacts)
  plan          pick a butterfly degree schedule (paper §IV-B)
  tune          measure this machine + dataset and pick the schedule
  shard         partition a dataset into on-disk worker shards
  pagerank      distributed PageRank on a synthetic power-law graph
  diameter      HADI effective-diameter estimation (OR-allreduce)
  sgd           distributed mini-batch SGD through the Comm session API
  train         distributed mini-batch SGD (XLA engine by default)
  worker        join a multi-process worker pool as a daemon
  launch        coordinate a worker pool: one JOIN, N jobs
  serve         serve remote collective clients against a worker pool
  serve-bench   measure serial vs multiplexed client serving (BENCH_6)
  replan        re-plan a serving pool's degree schedule in place
  replan-bench  measure stale vs re-planned schedules (BENCH_8)
  stat          pull a serving pool's merged obs snapshot
  obs-bench     measure instrumentation overhead (BENCH_9)
  trace         pull a pool's cross-worker round trace (Chrome JSON +
                critical-path report)
  trace-bench   measure trace-recording overhead (BENCH_10)
  config-check  validate a cluster config file
  help          show usage (`sar help <command>` for one command)

Run `sar help <command>` for per-command flags.
Set SAR_LOG=debug for verbose logging.";

/// Per-subcommand usage strings (`sar help <command>`).
pub fn usage_for(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "info" => "USAGE: sar info\n\nShow build/runtime info (PJRT platform, artifacts).",
        "plan" => "\
USAGE: sar plan [--mbytes f] [--machines m] [--floor-mb f] [--compression f]
                [--tune-profile tune.toml]

Pick a butterfly degree schedule (paper §IV-B).
  --mbytes f       per-node sparse payload in MiB        [16]
  --machines m     cluster size                          [64]
  --floor-mb f     effective packet floor in MiB         [2]
  --compression f  per-layer collision shrink factor     [0.7]
  --tune-profile p plan under a `sar tune` profile: its measured packet
                   floor and per-layer compression CURVE replace the
                   constants above (machines defaults to the profile's
                   world; conflicts with --floor-mb/--compression)",
        "tune" => "\
USAGE: sar tune [--dataset twitter|yahoo|docterm] [--scale f] [--seed s]
                [--world m] [--shards dir] [--out tune.toml]
                [--bench-json BENCH_3.json] [--warmup n] [--iters n]
                [--threads t] [--max-schedules n] [--fast]

Measurement-driven topology autotuning: microbenchmark the real
transports to fit the cost model (setup, bandwidth, packet floor), run
one real allreduce per candidate degree schedule on the actual dataset
to measure per-layer collision compression, rank the schedules under
the fitted model (paper Fig. 6), and persist the winner as a
digest-protected tuning profile that `sar launch --tune-profile` /
`sar pagerank --tune-profile` consume. Also emits a machine-readable
bench trajectory row (BENCH_*.json).
  --dataset d        synthetic dataset preset             [twitter]
  --scale f          dataset scale multiplier             [0.01]
  --seed s           RNG seed                             [42]
  --world m          machines to plan for                 [4]
  --shards dir       tune against a `sar shard` directory (shard count
                     fixes the world; overrides --world)
  --out path         tuning profile output                [tune.toml]
  --bench-json path  bench trajectory output              [BENCH_3.json]
  --warmup n         warmup iterations per measurement    [2; 1 with --fast]
  --iters n          measured iterations per measurement  [7; 3 with --fast]
  --threads t        sender threads assumed by the model  [8]
  --max-schedules n  cap on enumerated schedules          [64]
  --fast             CI smoke mode: fewer sizes/iterations",
        "shard" => "\
USAGE: sar shard --out <dir> [--workers m] [--dataset twitter|yahoo|docterm]
                 [--scale f] [--seed s] [--partition random|greedy]
                 [--edges path] [--from path]

Partition a dataset into on-disk worker shards: hash-permute the vertex
ids (the same permutation every PageRank driver applies), split the
edges across m shards, and write one CRC-protected binary shard file
per logical node plus a digest-protected manifest.toml. A later
`sar launch --shards <dir>` (or `sar pagerank --shards <dir>`) makes
each worker load only its own shard — no per-worker regeneration of the
global graph — and still land on the lockstep oracle's checksum.
  --out dir        output shard directory (required)
  --workers m      shard count = logical nodes of the later run  [4]
  --dataset d      synthetic dataset preset                      [twitter]
  --scale f        dataset scale multiplier                      [0.05]
  --seed s         permutation/partition seed — must match the
                   later run's --seed                            [42]
  --partition p    edge-partition strategy (random|greedy)       [random]
  --edges path     shard a `src dst` edge-list text file instead
                   of a synthetic preset (as-is, no cleanup)
  --from path      convert + shard a real download. A `.mtx` extension
                   parses Matrix Market coordinate format (general or
                   symmetric, values ignored, symmetric entries
                   mirrored); anything else parses as a SNAP-style edge
                   list (whitespace separated `src dst`, `#` comments).
                   Both collapse duplicate edges and canonicalize edge
                   order, so real downloads flow into the shard
                   pipeline deterministically",
        "pagerank" => "\
USAGE: sar pagerank [--mode lockstep|threaded|distributed|mp] [--distributed]
                    [--dataset twitter|yahoo|docterm] [--scale f]
                    [--degrees 16x4] [--tune-profile tune.toml]
                    [--replication r] [--iters n] [--pool host:port]
                    [--threads t] [--seed s] [--bin path] [--shards dir]

Distributed PageRank through the Comm session API.
  --mode m         execution mode                        [threaded]
                   lockstep|local: single-thread oracle
                   threaded|threads: one lane thread per node
                   distributed|multiprocess|mp|cluster: one OS
                   process per node over TCP
  --distributed    shorthand for --mode distributed
  --dataset d      synthetic dataset preset              [twitter]
  --scale f        dataset scale multiplier              [0.05]
  --degrees kxk    butterfly degree schedule             [4x2]
  --replication r  replicas per logical node (mode=distributed) [1]
  --iters n        PageRank iterations                   [10]
  --threads t      sender threads per node               [8]
  --seed s         RNG seed                              [42]
  --bin path       sar binary to spawn workers from (mode=distributed)
  --shards dir     load worker shards from a `sar shard` directory
                   (any mode) instead of regenerating the dataset
  --pool addr      run the collectives on a `sar serve`d worker pool
                   (implies --mode mp; --degrees must match the pool)
  --tune-profile p use the degree schedule + cost model from a
                   digest-verified `sar tune` profile (conflicts
                   with --degrees)",
        "diameter" => "\
USAGE: sar diameter [--mode lockstep|threaded|distributed|mp] [--dataset d]
                    [--scale f] [--degrees 4x2] [--sketches k]
                    [--max-h n] [--seed s] [--pool host:port]

HADI effective-diameter estimation (OR-allreduce) through the Comm
session API.
  --mode m       execution mode                          [lockstep]
                 in-process modes report the N(h) curve + effective
                 diameter (early-stops on saturation); distributed
                 runs --max-h fixed hops on a worker pool and reports
                 the cross-mode sketch checksum
  --dataset d    synthetic dataset preset                [twitter]
  --scale f      dataset scale multiplier                [0.05]
  --degrees kxk  butterfly degree schedule               [4x2]
  --sketches k   Flajolet–Martin sketches per vertex     [8]
  --max-h n      maximum hops                            [24]
  --seed s       RNG seed                                [7]
  --pool addr    run the collectives on a `sar serve`d worker pool
                 (implies --mode mp)",
        "sgd" => "\
USAGE: sar sgd [--mode lockstep|threaded|distributed|mp] [--features n]
               [--classes c] [--steps n] [--degrees 2x2] [--batch b]
               [--lr f] [--feats-per-ex k] [--seed s] [--pool host:port]

Distributed mini-batch SGD through the Comm session API: dynamic
per-step configs (the paper's §III-B mini-batch loop) with the
parameter-server bottom, NativeGradEngine in every mode so the
per-worker final losses are bit-comparable across modes.
  --mode m         execution mode                        [lockstep]
  --features n     raw feature-space size                [1024]
  --classes c      classes                               [8]
  --steps n        training steps                        [20]
  --degrees kxk    butterfly degree schedule             [2x2]
  --batch b        examples per worker per step          [32]
  --lr f           learning rate                         [0.5]
  --feats-per-ex k active features per example           [8]
  --seed s         RNG seed                              [123]
  --pool addr      run the collectives on a `sar serve`d worker pool
                   (implies --mode mp; per-step dynamic configs and the
                   parameter-server bottom run over the wire, model
                   state stays client-side)",
        "train" => "\
USAGE: sar train [--features n] [--classes c] [--steps n] [--degrees 2x2]
                 [--batch b] [--lr f] [--feats-per-ex k] [--native] [--seed s]

Distributed mini-batch SGD (XLA engine by default; --native for pure Rust).",
        "worker" => "\
USAGE: sar worker --coordinator host:port [--listen addr] [--advertise addr]
                  [--heartbeat-ms n]

Join a multi-process cluster: JOIN the coordinator, receive the plan,
run the config phase and reduce iterations, report metrics.
  --coordinator a  control-plane address (required)
  --listen a       data-plane bind address               [127.0.0.1:0]
  --advertise a    data-plane address peers should dial  [derived]
  --heartbeat-ms n control heartbeat interval            [100]",
        "launch" => "\
USAGE: sar launch [--jobs pagerank,diameter,...] [--workers n]
                  [--degrees 2x2] [--tune-profile tune.toml] [--elastic]
                  [--replication r] [--iters n]
                  [--dataset d] [--scale f] [--seed s] [--threads t]
                  [--bind addr] [--file cfg.toml] [--no-spawn] [--bin path]
                  [--shards dir] [--no-obs]

Coordinate a worker pool: gather worker JOINs once, then run each job
through its own CONFIG barrier → START → REPORT cycle on the same
pool — no worker restarts between jobs. Report lines are prefixed
with the job name so multi-job output is attributable.
  --jobs a,b,...   apps to run, in order (pagerank|diameter|sgd);
                   each inherits this launch's dataset/seed/iters
                   [pagerank]
  --workers n      expected worker count (must equal degrees × replication)
  --no-spawn       wait for externally-started workers instead of
                   forking them locally
  --bind a         control-plane bind address            [127.0.0.1:0]
  --bin path       sar binary to spawn local workers from [current exe]
  --file path      take topology/dataset settings from a config file
                   (`[run] jobs = \"pagerank,diameter\"` sets the job list)
  --shards dir     `sar shard` directory for pagerank jobs: workers
                   load + verify only their own shard (no per-worker
                   regeneration); the dir must be readable at the
                   same path on every worker host
  --tune-profile p use the degree schedule + cost model from a
                   digest-verified `sar tune` profile (conflicts
                   with --degrees; also settable as `[tune] profile`
                   in --file configs); the launch report prints
                   whether the profile stayed fresh against the live
                   pool view or drifted STALE
  --elastic        re-plan the degree schedule from the live pool view
                   between jobs (per-host calibration, graded health,
                   straggler streaks) — the lane count never changes,
                   so workers are never re-JOINed
  --no-obs         disable metric + trace recording pool-wide (the flag
                   rides the worker plan to every spawned worker)",
        "serve" => "\
USAGE: sar serve [--degrees 2x2] [--tune-profile tune.toml]
                 [--replication r] [--threads t]
                 [--bind addr] [--client-bind addr] [--sessions n]
                 [--queue n] [--keepalive-secs s] [--total-sessions n]
                 [--stats-every s] [--no-obs] [--no-spawn] [--bin path]

Serve remote collective clients against a worker pool: launch (or, with
--no-spawn, wait for) the workers, then accept client sessions on the
client port. A client streams its sparsity pattern (`configure`) and
per-round sparse values (`allreduce`), the workers run the app-agnostic
generic collective engine — SumF32 | OrU32 | MaxF32, including the
client-side allreduce_with_bottom — and reduced results stream back.
No app name ever crosses the wire, so ANY workload runs distributed.
The serve plane is multi-tenant: up to --sessions clients share the
pool concurrently (each in its own job-scoped tag space), arrivals past
the limit wait in a bounded queue, complete rounds dispatch round-robin
across sessions, and a session idle past the keepalive is evicted with
its worker state released. Clients connect with
`CommBuilder::pool(addr)` or the `--pool` flag of sar
pagerank/diameter/sgd.
With --replication r the pool runs r workers per logical lane (paper
§V): every lane's CONFIGURE/VALUES fans out to all its replicas, the
first RESULT per lane wins, and a worker death mid-round is masked —
client sessions keep running, with identical results, as long as every
lane keeps one live replica. Replicas are placed on distinct hosts when
the joined workers' addresses allow it.
  --degrees kxk       butterfly degree schedule over the pool [2x2]
  --replication r     workers per logical lane (fault masking) [1]
  --threads t         sender threads per worker               [4]
  --bind a            worker control-plane bind address       [127.0.0.1:0]
  --client-bind a     client-facing bind address              [127.0.0.1:0]
  --sessions n        concurrent live client session limit    [4]
  --queue n           wait-queue depth past the live limit    [16]
  --keepalive-secs s  evict sessions idle this long           [120]
  --total-sessions n  serve n sessions in total, then release the pool
                      (default: serve until killed)
  --stats-every s     print a serve-plane stat line every s seconds
                      (served/live/queued/evicted/rejected/rounds and
                      the dispatch p50); `sar stat --pool` pulls the
                      full cluster snapshot on demand
  --no-obs            disable metric + trace recording POOL-WIDE: the
                      flag rides the worker plan, so spawned workers
                      record nothing either (`sar stat` then reads
                      zeros and `sar trace` an empty timeline)
  --no-spawn          wait for externally-started workers instead of
                      forking them locally
  --bin path          sar binary to spawn local workers from  [current exe]
  --tune-profile p    take the degree schedule from a digest-verified
                      `sar tune` profile (conflicts with --degrees) and
                      track its freshness against the live pool view —
                      the exit line reports when it drifted STALE",
        "serve-bench" => "\
USAGE: sar serve-bench [--degrees 2x2] [--threads t] [--rounds n]
                       [--out BENCH_6.json] [--bin path] [--fast]

Measure the multi-tenant serve plane's headline: the wall-clock of two
collective clients served serially vs multiplexed on one pool. Each
client configures its own sparsity pattern and runs --rounds SumF32
allreduces; every run's checksum is validated against the lockstep
oracle before any timing is recorded. Emits the machine-readable
trajectory row (BENCH_6.json).
  --degrees kxk    butterfly degree schedule over the pool [2x2]
  --threads t      sender threads per worker               [2]
  --rounds n       allreduce rounds per client session     [16]
  --out path       bench trajectory output                 [BENCH_6.json]
  --bin path       sar binary to spawn pool workers from   [current exe]
  --fast           CI smoke mode: fewer iterations",
        "replan" => "\
USAGE: sar replan --pool host:port [--degrees 2x2]

Re-plan a serving pool's degree schedule in place (elastic control
plane): connect to a `sar serve` pool's client port and request a
REPLAN. The serve plane waits for a quiescent point (no client session
holding collective state), walks the REPLAN → REPLAN_DONE barrier on
the workers, and later sessions run the new schedule — the workers
never re-JOIN, because degrees shape each job's butterflies, not the
once-built TCP fabric. The adopted schedule is printed on success.
  --pool addr    the pool's client port (required)
  --degrees kxk  schedule to adopt; its product must keep the pool's
                 logical lane count. Omit to re-plan automatically from
                 the live pool view: per-host calibration constants
                 (workers microbench themselves at startup), graded
                 health, and RTT straggler streaks — consistent
                 stragglers shrink the planned degrees",
        "replan-bench" => "\
USAGE: sar replan-bench [--lanes n] [--rounds n] [--mbytes f]
                        [--out BENCH_8.json] [--fast]

Measure the elastic control plane's headline: per-round allreduce time
on a pool with one skewed (high-setup, straggling) host, under the
stale uniform schedule vs the schedule re-planned from the live view
(the straggler-penalized cost fold picks smaller degrees). Runs
in-process over a delay-modelled transport so the skew is
deterministic; checksums validate against the lockstep oracle before
any timing is recorded. Emits the machine-readable trajectory row
(BENCH_8.json).
  --lanes n    logical lanes in the modelled pool      [4]
  --rounds n   timed allreduce rounds per schedule     [12]
  --mbytes f   per-node sparse payload in MiB          [4]
  --out path   bench trajectory output                 [BENCH_8.json]
  --fast       CI smoke mode: fewer rounds",
        "stat" => "\
USAGE: sar stat --pool host:port [--json]

Pull the cluster-wide observability snapshot off a `sar serve` pool:
connect to the pool's client port (the same admin door `sar replan`
uses) and request STATS. The coordinator pulls every live worker's
metric registry over the control plane — per-round phase latencies
(scatter/reduce/gather/merge/wire), bytes in/out per layer, engine
round counts — folds in its own serve-plane census (admissions,
rejections, evictions, queue depth, dispatch latency, per-session
round counts), and answers with the merged rollup.
  --pool addr  the pool's client port (required)
  --json       print the raw JSON rollup (workers/serve/cluster keys;
               histograms carry count, sum_us, mean/p50/p99 seconds,
               and the 26 log2-microsecond buckets) instead of the
               human table",
        "obs-bench" => "\
USAGE: sar obs-bench [--lanes n] [--rounds n] [--out BENCH_9.json] [--fast]

Measure the observability plane's overhead: per-round threaded
allreduce time with the obs registry recording (spans + counters on
the scatter/reduce/gather/merge/wire paths) vs disabled (the --no-obs
gate). Both cases' checksums are validated against the lockstep oracle
before any timing is reported. Emits the machine-readable trajectory
row (BENCH_9.json).
  --lanes n    logical lanes (threaded, one thread each) [4]
  --rounds n   timed allreduce rounds per case           [48]
  --out path   bench trajectory output                   [BENCH_9.json]
  --fast       CI smoke mode: fewer rounds",
        "trace" => "\
USAGE: sar trace --pool host:port [--out trace.json] [--tune-profile p]

Pull the distributed round trace off a `sar serve` pool: connect to
the pool's client port (the same admin door `sar stat` uses) and
request TRACE. The coordinator pulls every worker's trace ring over
the control plane — round/config container spans, per-butterfly-layer
scatter/reduce/gather spans, per-wire-edge flow events with byte
counts, worker-engine dispatch, serve-plane admission/dispatch/drain
marks — re-bases each worker's timestamps onto its own clock (midpoint
offset estimate, accurate to half the control round trip,
drift-checked across pulls), and answers with one merged timeline.
Writes Chrome trace-event JSON (load it in chrome://tracing or
Perfetto: one track per worker plus the serve track) and prints a
per-round critical-path report: the bounding lane's chain of phase
spans, the slowest (lane, layer) span, and each layer's achieved wire
bandwidth — compared against the fitted cost model when a tuning
profile is given.
  --pool addr      the pool's client port (required)
  --out path       Chrome trace JSON output               [trace.json]
  --tune-profile p compare each layer's achieved bandwidth against a
                   digest-verified `sar tune` profile's fitted model",
        "trace-bench" => "\
USAGE: sar trace-bench [--lanes n] [--rounds n] [--out BENCH_10.json] [--fast]

Measure the trace plane's overhead: per-round threaded allreduce time
with trace recording on (container + layer spans and one flow event
per wire edge, into the per-process ring) vs fully disabled (the
--no-obs gate). Both cases' checksums are validated against the
lockstep oracle before any timing is reported. Emits the
machine-readable trajectory row (BENCH_10.json).
  --lanes n    logical lanes (threaded, one thread each) [4]
  --rounds n   timed allreduce rounds per case           [48]
  --out path   bench trajectory output                   [BENCH_10.json]
  --fast       CI smoke mode: fewer rounds",
        "config-check" => "\
USAGE: sar config-check --file <path>

Validate a cluster config file (TOML subset).",
        "help" => "USAGE: sar help [command]",
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args(&["pagerank", "--iters", "10", "--degrees=16x4", "--verbose"]);
        assert_eq!(a.subcommand, "pagerank");
        assert_eq!(a.flag("iters"), Some("10"));
        assert_eq!(a.flag("degrees"), Some("16x4"));
        assert!(a.has_switch("verbose"));
        assert!(!a.has_switch("quiet"));
    }

    #[test]
    fn typed_flags() {
        let a = args(&["x", "--n", "5", "--f", "2.5"]);
        assert_eq!(a.usize_flag("n", 1).unwrap(), 5);
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
        assert!((a.f64_flag("f", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!(a.usize_flag("f", 0).is_err());
    }

    #[test]
    fn degrees_formats() {
        assert_eq!(parse_degrees("16x4").unwrap(), vec![16, 4]);
        assert_eq!(parse_degrees("8,4,2").unwrap(), vec![8, 4, 2]);
        assert_eq!(parse_degrees("64").unwrap(), vec![64]);
        assert!(parse_degrees("0x4").is_err());
        assert!(parse_degrees("ax4").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["cmd".to_string(), "stray".to_string()]).is_err());
    }

    #[test]
    fn help_takes_a_topic_positional() {
        let a = args(&["help", "pagerank"]);
        assert_eq!(a.subcommand, "help");
        assert_eq!(a.positional(0), Some("pagerank"));
        assert_eq!(a.positional(1), None);
        // both help spellings accept the topic
        let a = args(&["--help", "launch"]);
        assert_eq!(a.positional(0), Some("launch"));
    }

    #[test]
    fn every_command_has_usage() {
        for cmd in [
            "info", "plan", "tune", "shard", "pagerank", "diameter", "sgd", "train", "worker",
            "launch", "serve", "serve-bench", "replan", "replan-bench", "stat", "obs-bench",
            "trace", "trace-bench", "config-check", "help",
        ] {
            assert!(usage_for(cmd).is_some(), "missing usage for {cmd}");
            assert!(USAGE.contains(cmd), "top-level usage missing {cmd}");
        }
        assert!(usage_for("bogus").is_none());
    }

    #[test]
    fn unknown_flags_point_at_usage() {
        let a = args(&["pagerank", "--itres", "10"]);
        let err = a.expect_known("pagerank", &["iters", "seed"]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--itres"), "should name the bad flag: {msg}");
        assert!(msg.contains("USAGE: sar pagerank"), "should include usage: {msg}");
        assert!(a.expect_known("pagerank", &["itres"]).is_ok());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
