//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `sar <subcommand> [--flag value]... [--switch]...`
//! Flags may also be written `--flag=value`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument `{arg}`");
            };
            if name.is_empty() {
                bail!("empty flag name");
            }
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap());
            } else {
                switches.push(name.to_string());
            }
        }
        Ok(Args { subcommand, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    /// Parse `--degrees 16x4` (or `16,4`) into a degree schedule.
    pub fn degrees_flag(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flag(name) {
            None => Ok(default.to_vec()),
            Some(v) => parse_degrees(v),
        }
    }
}

/// Parse a degree schedule like `16x4`, `8x4x2` or `16,4`.
pub fn parse_degrees(s: &str) -> Result<Vec<usize>> {
    let parts: Vec<&str> = s.split(['x', ',', 'X']).collect();
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        let k: usize = p.trim().parse().map_err(|_| anyhow::anyhow!("bad degree `{p}` in `{s}`"))?;
        if k == 0 {
            bail!("degree 0 in `{s}`");
        }
        out.push(k);
    }
    if out.is_empty() {
        bail!("empty degree schedule");
    }
    Ok(out)
}

/// Top-level usage text.
pub const USAGE: &str = "\
sparse-allreduce (sar) — Sparse Allreduce for power-law data (Zhao & Canny 2013)

USAGE: sar <command> [flags]

COMMANDS:
  info                         show build/runtime info (PJRT platform, artifacts)
  plan      --mbytes <f> --machines <m> [--floor-mb <f>]
                               pick a butterfly degree schedule (paper §IV-B)
  pagerank  [--dataset twitter|yahoo|docterm] [--scale f] [--degrees 16x4]
            [--iters n] [--threads t] [--seed s]
                               distributed PageRank on a synthetic power-law graph
  diameter  [--scale f] [--degrees 4x2] [--sketches k] [--seed s]
                               HADI effective-diameter estimation (OR-allreduce)
  train     [--features n] [--classes c] [--steps n] [--degrees 2x2]
            [--batch b] [--lr f] [--native] [--seed s]
                               distributed mini-batch SGD (XLA engine by default)
  config-check --file <path>   validate a cluster config file

Set SAR_LOG=debug for verbose logging.";

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args(&["pagerank", "--iters", "10", "--degrees=16x4", "--verbose"]);
        assert_eq!(a.subcommand, "pagerank");
        assert_eq!(a.flag("iters"), Some("10"));
        assert_eq!(a.flag("degrees"), Some("16x4"));
        assert!(a.has_switch("verbose"));
        assert!(!a.has_switch("quiet"));
    }

    #[test]
    fn typed_flags() {
        let a = args(&["x", "--n", "5", "--f", "2.5"]);
        assert_eq!(a.usize_flag("n", 1).unwrap(), 5);
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
        assert!((a.f64_flag("f", 0.0).unwrap() - 2.5).abs() < 1e-12);
        assert!(a.usize_flag("f", 0).is_err());
    }

    #[test]
    fn degrees_formats() {
        assert_eq!(parse_degrees("16x4").unwrap(), vec![16, 4]);
        assert_eq!(parse_degrees("8,4,2").unwrap(), vec![8, 4, 2]);
        assert_eq!(parse_degrees("64").unwrap(), vec![64]);
        assert!(parse_degrees("0x4").is_err());
        assert!(parse_degrees("ax4").is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["cmd".to_string(), "stray".to_string()]).is_err());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
