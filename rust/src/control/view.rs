//! The coordinator's live fingerprint of a worker pool, and drift
//! detection against the tuning profile that planned it.

use crate::fault::Health;
use crate::simnet::CostModel;
use crate::tune::TuneProfile;
use std::fmt;

/// One host's measured cost constants, as reported by the worker's
/// on-host calibration (`CtrlMsg::Calibration`).
#[derive(Clone, Debug, PartialEq)]
pub struct HostConstants {
    /// Transport the worker calibrated on (`mem` for the on-host echo
    /// microbench).
    pub transport: String,
    pub model: CostModel,
}

/// A live snapshot of a running pool: everything the elastic control
/// loop plans against. Built by the coordinator (`Session::pool_view`)
/// from its own plan, the failure detector's grades, the RTT straggler
/// streaks, and the per-host calibration reports.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolView {
    /// Physical worker count (`logical × replication`).
    pub world: usize,
    pub replication: usize,
    /// The degree schedule the pool currently runs.
    pub degrees: Vec<usize>,
    /// Graded health, one per physical worker.
    pub grades: Vec<Health>,
    /// Consecutive RTT-straggler readouts, one per physical worker
    /// (reset to 0 whenever the readout names someone else).
    pub straggler_streaks: Vec<u32>,
    /// Per-host calibration constants (`None` until the worker's
    /// background calibration reports, or when its fit failed).
    pub host_constants: Vec<Option<HostConstants>>,
    /// Wire the pool's data plane runs on (`tcp` for multi-process
    /// pools, `mem` for in-process drivers).
    pub transport: String,
}

impl PoolView {
    /// Logical lane count — the invariant a re-plan must preserve.
    pub fn logical(&self) -> usize {
        self.world / self.replication.max(1)
    }

    /// How many hosts have reported calibration constants.
    pub fn calibrated_hosts(&self) -> usize {
        self.host_constants.iter().filter(|c| c.is_some()).count()
    }

    /// Worst measured floor across live calibrated hosts at `frac`
    /// efficiency — the number the §IV-B planner needs. `None` until at
    /// least one live host has reported.
    pub fn worst_live_floor(&self, frac: f64) -> Option<f64> {
        self.live_models()
            .map(|(_, m)| m.floor_bytes(frac))
            .fold(None, |acc, f| Some(acc.map_or(f, |a: f64| a.max(f))))
    }

    /// Calibrated cost models of workers not graded Unhealthy.
    pub fn live_models(&self) -> impl Iterator<Item = (usize, CostModel)> + '_ {
        self.host_constants.iter().enumerate().filter_map(|(w, c)| {
            let c = c.as_ref()?;
            if self.grades.get(w).copied().unwrap_or(Health::Normal) == Health::Unhealthy {
                None
            } else {
                Some((w, c.model))
            }
        })
    }
}

impl fmt::Display for PoolView {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sched =
            self.degrees.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("x");
        let degraded = self.grades.iter().filter(|&&g| g != Health::Normal).count();
        write!(
            f,
            "world {} (x{} replication), degrees {sched}, {} degraded, {}/{} calibrated",
            self.world,
            self.replication,
            degraded,
            self.calibrated_hosts(),
            self.world
        )
    }
}

/// One way the live pool has drifted from the view a tuning profile was
/// derived under. A non-empty drift list marks the profile stale.
#[derive(Clone, Debug, PartialEq)]
pub enum Drift {
    /// The profile plans a different logical world than the pool runs.
    World { profile: usize, live: usize },
    /// The profile's constants were calibrated on a different transport
    /// than the pool's data plane.
    Transport { profile: String, live: String },
    /// Workers have degraded past Normal since the profile was fitted.
    Health { suspect: usize, unhealthy: usize },
    /// The worst live measured packet floor disagrees with the
    /// profile's by more than the tolerated ratio.
    PacketFloor { profile: f64, live: f64 },
}

impl fmt::Display for Drift {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Drift::World { profile, live } => {
                write!(f, "world changed (profile {profile}, live {live})")
            }
            Drift::Transport { profile, live } => {
                write!(f, "transport changed (profile `{profile}`, pool `{live}`)")
            }
            Drift::Health { suspect, unhealthy } => {
                write!(f, "{suspect} suspect / {unhealthy} unhealthy workers")
            }
            Drift::PacketFloor { profile, live } => {
                write!(f, "packet floor drifted (profile {profile:.0} B, measured {live:.0} B)")
            }
        }
    }
}

/// Allowed ratio between the profile's packet floor and the worst live
/// measured one before the profile counts as drifted. Generous: host
/// microbenches are noisy, and a factor-of-a-few disagreement barely
/// moves the greedy planner.
pub const FLOOR_DRIFT_RATIO: f64 = 8.0;

/// Compare the live pool view against the view a profile was tuned
/// under. Empty = fresh; each entry is one independent staleness
/// reason, printable as the launch report's staleness line.
pub fn profile_drift(profile: &TuneProfile, view: &PoolView) -> Vec<Drift> {
    let mut drifts = Vec::new();
    if profile.world != view.logical() {
        drifts.push(Drift::World { profile: profile.world, live: view.logical() });
    }
    // An unrecorded transport (legacy profile) cannot prove a mismatch;
    // the hard mem-on-tcp case is the one the tune satellite rejects.
    let compatible = match (profile.transport.as_str(), view.transport.as_str()) {
        ("", _) => true,
        ("tcp-loopback", "tcp") | ("mem", "mem") => true,
        (p, l) => p == l,
    };
    if !compatible {
        drifts.push(Drift::Transport {
            profile: profile.transport.clone(),
            live: view.transport.clone(),
        });
    }
    let suspect = view.grades.iter().filter(|&&g| g == Health::Suspect).count();
    let unhealthy = view.grades.iter().filter(|&&g| g == Health::Unhealthy).count();
    if suspect + unhealthy > 0 {
        drifts.push(Drift::Health { suspect, unhealthy });
    }
    if let Some(live_floor) = view.worst_live_floor(0.6) {
        let ratio = live_floor / profile.packet_floor.max(f64::MIN_POSITIVE);
        if !(1.0 / FLOOR_DRIFT_RATIO..=FLOOR_DRIFT_RATIO).contains(&ratio) {
            drifts.push(Drift::PacketFloor { profile: profile.packet_floor, live: live_floor });
        }
    }
    drifts
}

/// Render a drift list as the one-line staleness verdict the launch
/// report and serve exit line print.
pub fn drift_line(drifts: &[Drift]) -> String {
    if drifts.is_empty() {
        "tune profile fresh (matches live pool view)".to_string()
    } else {
        let reasons = drifts.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ");
        format!("tune profile STALE: {reasons}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::profile::TUNE_FORMAT;

    fn fresh_profile() -> TuneProfile {
        TuneProfile {
            format: TUNE_FORMAT,
            world: 4,
            degrees: vec![2, 2],
            cost: CostModel {
                setup_secs: 1e-4,
                bandwidth_bps: 1e9,
                outlier_prob: 0.0,
                outlier_mean_secs: 0.0,
            },
            transport: "tcp-loopback".into(),
            packet_floor: 150_000.0,
            compression: vec![0.7],
            dataset: "twitter".into(),
            scale: 0.01,
            seed: 42,
        }
    }

    fn matching_view() -> PoolView {
        PoolView {
            world: 4,
            replication: 1,
            degrees: vec![2, 2],
            grades: vec![Health::Normal; 4],
            straggler_streaks: vec![0; 4],
            host_constants: vec![None; 4],
            transport: "tcp".into(),
        }
    }

    #[test]
    fn matching_view_is_fresh() {
        let drifts = profile_drift(&fresh_profile(), &matching_view());
        assert_eq!(drifts, Vec::new());
        assert!(drift_line(&drifts).contains("fresh"));
    }

    #[test]
    fn world_and_transport_drift_are_detected() {
        let mut view = matching_view();
        view.world = 8;
        view.replication = 1;
        let drifts = profile_drift(&fresh_profile(), &view);
        assert_eq!(drifts, vec![Drift::World { profile: 4, live: 8 }]);
        assert!(drift_line(&drifts).contains("STALE"), "{}", drift_line(&drifts));

        // Replication does not change the logical world the profile
        // plans: 4 lanes x 2 replicas still matches a world-4 profile.
        let mut replicated = matching_view();
        replicated.world = 8;
        replicated.replication = 2;
        replicated.grades = vec![Health::Normal; 8];
        replicated.straggler_streaks = vec![0; 8];
        replicated.host_constants = vec![None; 8];
        assert_eq!(profile_drift(&fresh_profile(), &replicated), Vec::new());

        let mem = TuneProfile { transport: "mem".into(), ..fresh_profile() };
        let drifts = profile_drift(&mem, &matching_view());
        assert_eq!(
            drifts,
            vec![Drift::Transport { profile: "mem".into(), live: "tcp".into() }]
        );
        // Legacy profiles (no transport recorded) cannot prove mismatch.
        let legacy = TuneProfile { transport: String::new(), ..fresh_profile() };
        assert_eq!(profile_drift(&legacy, &matching_view()), Vec::new());
    }

    #[test]
    fn degraded_health_marks_the_profile_stale() {
        let mut view = matching_view();
        view.grades[1] = Health::Suspect;
        view.grades[3] = Health::Unhealthy;
        let drifts = profile_drift(&fresh_profile(), &view);
        assert_eq!(drifts, vec![Drift::Health { suspect: 1, unhealthy: 1 }]);
        let line = drift_line(&drifts);
        assert!(line.contains("1 suspect") && line.contains("1 unhealthy"), "{line}");
    }

    #[test]
    fn measured_floor_drift_marks_the_profile_stale() {
        let mut view = matching_view();
        // Host 2 measured a floor ~67x the profile's: drifted.
        view.host_constants[2] = Some(HostConstants {
            transport: "mem".into(),
            model: CostModel {
                setup_secs: 1e-2,
                bandwidth_bps: 1e9,
                ..CostModel::ideal(1e9)
            },
        });
        let drifts = profile_drift(&fresh_profile(), &view);
        assert_eq!(drifts.len(), 1, "{drifts:?}");
        assert!(matches!(drifts[0], Drift::PacketFloor { .. }), "{drifts:?}");
        // A floor within the tolerance band is NOT drift.
        view.host_constants[2] = Some(HostConstants {
            transport: "mem".into(),
            model: CostModel {
                setup_secs: 1e-4,
                bandwidth_bps: 1e9,
                ..CostModel::ideal(1e9)
            },
        });
        assert_eq!(profile_drift(&fresh_profile(), &view), Vec::new());
        // ...and an Unhealthy host's constants are ignored entirely.
        view.host_constants[2] = Some(HostConstants {
            transport: "mem".into(),
            model: CostModel {
                setup_secs: 10.0,
                bandwidth_bps: 1e9,
                ..CostModel::ideal(1e9)
            },
        });
        view.grades[2] = Health::Unhealthy;
        let drifts = profile_drift(&fresh_profile(), &view);
        assert_eq!(drifts, vec![Drift::Health { suspect: 0, unhealthy: 1 }]);
    }

    #[test]
    fn view_accessors() {
        let mut view = matching_view();
        view.host_constants[0] = Some(HostConstants {
            transport: "mem".into(),
            model: CostModel::ideal(1e9),
        });
        assert_eq!(view.logical(), 4);
        assert_eq!(view.calibrated_hosts(), 1);
        let line = format!("{view}");
        assert!(line.contains("world 4") && line.contains("1/4 calibrated"), "{line}");
    }
}
