//! Elastic control plane: the online re-plan/re-tune loop (ROADMAP
//! item 4).
//!
//! The paper derives the optimum heterogeneous-degree butterfly from
//! measured machine constants and data statistics — but `sar tune` does
//! that derivation exactly once, offline, and the profile goes silently
//! stale when the pool it describes changes. This module turns the
//! one-shot autotuner into a living part of the cluster plane, with
//! four cooperating pieces:
//!
//! 1. **Drift detection** ([`view`]): the coordinator maintains a
//!    [`PoolView`] fingerprint of the live pool — world, replication,
//!    per-lane health grade, and per-host fitted cost constants — and
//!    [`profile_drift`] compares it against the view baked into
//!    `tune.toml`/the `WorkerPlan`. A drifted profile is *reported
//!    stale* (launch report, `ServeStats`) instead of silently driving
//!    2013-shaped degrees.
//! 2. **Between-job re-plan** ([`replan`]): [`plan_for_view`] re-runs
//!    the §IV-B planner against the live view, and the cluster plane's
//!    `CtrlMsg::Replan` cycle swaps the degree schedule on a running
//!    pool between jobs (and between serve-plane sessions at a
//!    quiescent point) without re-JOINing a single worker — the degrees
//!    only shape per-job butterflies, never the once-built TCP fabric.
//! 3. **On-worker calibration**: workers run the echo microbench
//!    host-side at bring-up and ship `CostModel::fit` constants back in
//!    a `CtrlMsg::Calibration`; the coordinator folds them into the
//!    view so re-planning uses each host's measured floor.
//! 4. **Straggler-aware assignment**: the nonce'd-RTT health grades
//!    feed the fold — a consistently-Suspect host's constants are
//!    penalized, raising the effective packet floor and shrinking the
//!    butterfly degrees the pool re-plans to (Yan et al.'s
//!    shift-work-off-stragglers direction, PAPERS.md).

pub mod replan;
pub mod view;

pub use replan::{plan_for_view, ReplanParams, CONSISTENT_STREAK};
pub use view::{profile_drift, Drift, HostConstants, PoolView};
