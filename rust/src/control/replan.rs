//! Re-planning a live pool's degree schedule from its [`PoolView`].
//!
//! The §IV-B planner ([`crate::topology::plan_degrees_curve`]) needs a
//! packet floor and a compression curve. Offline, `sar tune` measures
//! both once; here the floor comes from the pool's *live* per-host
//! calibration constants, folded worst-host-wins, with
//! consistently-straggling hosts penalized so the schedule shifts work
//! off them: a penalized setup cost raises the effective floor, and a
//! higher floor makes the greedy planner pick *smaller* butterfly
//! degrees (fewer, larger packets per layer) — exactly the adjustment
//! the paper prescribes when per-message overhead grows.

use super::view::PoolView;
use crate::fault::Health;
use crate::simnet::CostModel;
use crate::topology::{plan_degrees_curve, PlannerParams};

/// Consecutive RTT-straggler readouts after which a host counts as
/// *consistently* slow and its constants are penalized in the fold.
/// One slow heartbeat never re-shapes the pool.
pub const CONSISTENT_STREAK: u32 = 3;

/// Knobs for deriving a schedule from a live view.
#[derive(Clone, Debug)]
pub struct ReplanParams {
    /// Per-node sparse payload entering layer 0, bytes.
    pub bytes_per_node: f64,
    /// Measured per-layer compression curve (empty = the planner's
    /// constant default).
    pub compression: Vec<f64>,
    /// Efficiency fraction defining the packet floor (`sar tune` uses
    /// 0.6).
    pub floor_frac: f64,
    /// Multiplier on a consistently-straggling host's setup cost before
    /// the worst-host fold.
    pub straggler_penalty: f64,
    /// Model used when no live host has reported calibration constants.
    pub fallback: CostModel,
}

impl Default for ReplanParams {
    fn default() -> Self {
        Self {
            bytes_per_node: 16.0 * 1024.0 * 1024.0,
            compression: Vec::new(),
            floor_frac: 0.6,
            straggler_penalty: 4.0,
            fallback: CostModel::ec2_2013(),
        }
    }
}

/// Fold the view's live per-host constants into one planning model:
/// worst setup and worst bandwidth across hosts (a butterfly layer is
/// only as fast as its slowest lane), with consistently-straggling
/// hosts' setup costs inflated by the penalty first. Falls back to
/// `params.fallback` when no live host has calibrated.
pub fn folded_model(view: &PoolView, params: &ReplanParams) -> CostModel {
    let mut folded: Option<CostModel> = None;
    for (w, model) in view.live_models() {
        let consistent = view.straggler_streaks.get(w).copied().unwrap_or(0)
            >= CONSISTENT_STREAK
            || view.grades.get(w).copied().unwrap_or(Health::Normal) == Health::Suspect;
        let setup =
            if consistent { model.setup_secs * params.straggler_penalty } else { model.setup_secs };
        let f = folded.get_or_insert(CostModel {
            setup_secs: setup,
            bandwidth_bps: model.bandwidth_bps,
            outlier_prob: 0.0,
            outlier_mean_secs: 0.0,
        });
        f.setup_secs = f.setup_secs.max(setup);
        f.bandwidth_bps = f.bandwidth_bps.min(model.bandwidth_bps);
    }
    folded.unwrap_or(params.fallback)
}

/// Derive the degree schedule the live pool should run: fold the
/// per-host constants, turn them into a packet floor, and run the
/// greedy §IV-B planner over the pool's logical lanes. The product
/// always equals `view.logical()`, so adopting the result never needs
/// a re-JOIN.
pub fn plan_for_view(view: &PoolView, params: &ReplanParams) -> Vec<usize> {
    let model = folded_model(view, params);
    let planner = PlannerParams {
        bytes_per_node: params.bytes_per_node,
        packet_floor: model.floor_bytes(params.floor_frac),
        compression: 0.7,
    };
    plan_degrees_curve(view.logical(), &planner, &params.compression)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::view::HostConstants;

    fn host(setup: f64, bandwidth: f64) -> Option<HostConstants> {
        Some(HostConstants {
            transport: "mem".into(),
            model: CostModel {
                setup_secs: setup,
                bandwidth_bps: bandwidth,
                outlier_prob: 0.0,
                outlier_mean_secs: 0.0,
            },
        })
    }

    fn view4() -> PoolView {
        PoolView {
            world: 4,
            replication: 1,
            degrees: vec![2, 2],
            grades: vec![Health::Normal; 4],
            straggler_streaks: vec![0; 4],
            host_constants: vec![None; 4],
            transport: "tcp".into(),
        }
    }

    /// The fold is worst-host-wins on both constants, and the fallback
    /// fires only when nobody has calibrated.
    #[test]
    fn fold_takes_the_worst_live_host() {
        let params = ReplanParams::default();
        let mut view = view4();
        assert_eq!(folded_model(&view, &params), params.fallback);
        view.host_constants[0] = host(1e-4, 2e9);
        view.host_constants[2] = host(5e-4, 1e9);
        let m = folded_model(&view, &params);
        assert_eq!(m.setup_secs, 5e-4);
        assert_eq!(m.bandwidth_bps, 1e9);
        // An Unhealthy host's constants drop out of the fold.
        view.grades[2] = Health::Unhealthy;
        let m = folded_model(&view, &params);
        assert_eq!(m.setup_secs, 1e-4);
        assert_eq!(m.bandwidth_bps, 2e9);
    }

    /// The headline behavior: a consistently-straggling host raises the
    /// folded floor and the planner answers with *smaller* degrees,
    /// while a single slow readout (streak below the threshold) changes
    /// nothing.
    #[test]
    fn consistent_straggler_shrinks_the_planned_degrees() {
        // 4 MiB/node, floor ~1 MiB healthy: bytes/4 ≥ floor → plan [4].
        let params = ReplanParams {
            bytes_per_node: 4.0 * 1024.0 * 1024.0,
            straggler_penalty: 4.0,
            ..ReplanParams::default()
        };
        let mut view = view4();
        for c in view.host_constants.iter_mut() {
            // floor(0.6) = setup · bw · 1.5 ≈ 0.98 MiB
            *c = host(6.5e-4, 1.05e9);
        }
        assert_eq!(plan_for_view(&view, &params), vec![4]);
        // One slow heartbeat: streak 1 < CONSISTENT_STREAK, same plan.
        view.straggler_streaks[3] = 1;
        assert_eq!(plan_for_view(&view, &params), vec![4]);
        // Consistent straggler: 4x setup → floor ~3.9 MiB; bytes/4 and
        // bytes/2 both violate it → binary butterfly.
        view.straggler_streaks[3] = CONSISTENT_STREAK;
        let d = plan_for_view(&view, &params);
        assert_eq!(d, vec![2, 2], "penalized floor must shrink the degrees");
        assert_eq!(d.iter().product::<usize>(), view.logical(), "no re-JOIN: lanes preserved");
    }

    /// A Suspect grade (the detector's own verdict) penalizes the host
    /// even before the streak counter accumulates.
    #[test]
    fn suspect_grade_is_penalized_like_a_streak() {
        let params = ReplanParams::default();
        let mut view = view4();
        view.host_constants[1] = host(1e-4, 1e9);
        view.grades[1] = Health::Suspect;
        let m = folded_model(&view, &params);
        assert_eq!(m.setup_secs, 4e-4, "suspect host's setup must be penalized");
    }

    /// Replication plans over logical lanes, not physical workers.
    #[test]
    fn replicated_view_plans_logical_lanes() {
        let view = PoolView {
            world: 8,
            replication: 2,
            degrees: vec![2, 2],
            grades: vec![Health::Normal; 8],
            straggler_streaks: vec![0; 8],
            host_constants: vec![None; 8],
            transport: "tcp".into(),
        };
        let params = ReplanParams {
            bytes_per_node: 256.0 * 1024.0 * 1024.0,
            ..ReplanParams::default()
        };
        let d = plan_for_view(&view, &params);
        assert_eq!(d.iter().product::<usize>(), 4, "8 workers / 2 replicas = 4 lanes");
    }
}
