//! [`CommBuilder`]: fix a communicator's shape once, then open sessions
//! or run jobs under any execution mode.

use super::job::{JobOutcome, JobSpec};
use super::remote::RemoteSession;
use super::session::{PoolBackend, Session};
use super::{run, ExecMode};
use crate::config::validate_world;
use crate::simnet::CostModel;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Builder for a communicator session (see [`crate::comm`] module docs).
///
/// ```no_run
/// use sparse_allreduce::comm::{CommBuilder, ExecMode, JobSpec};
/// use sparse_allreduce::sparse::{IndexSet, SumF32};
///
/// // The primitive door: configure once per sparsity pattern, then
/// // allreduce repeatedly — the paper's two-phase lifecycle.
/// let mut sess = CommBuilder::new(vec![2, 2])
///     .mode(ExecMode::Threaded)
///     .send_threads(4)
///     .build(1024)?; // allreduce index domain [0, 1024)
/// let out: Vec<IndexSet> = (0..4).map(|n| IndexSet::from_unsorted(vec![n, 100])).collect();
/// let inb: Vec<IndexSet> = (0..4).map(|_| IndexSet::from_unsorted(vec![100])).collect();
/// let mut cfg = sess.configure(out, inb)?;
/// for _ in 0..10 {
///     let mut values = vec![vec![1.0f32, 0.5]; 4];
///     cfg.allreduce::<SumF32>(&mut values)?; // values now hold the reduced inbound
/// }
///
/// // The whole-app door: the same builder runs any packaged job in any
/// // mode (a multi-process submit spawns a worker pool under the hood).
/// let outcome = CommBuilder::new(vec![2, 2])
///     .mode(ExecMode::Lockstep)
///     .submit(&JobSpec::diameter())?;
/// println!("checksum {}", outcome.checksum);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Clone, Debug)]
pub struct CommBuilder {
    degrees: Vec<usize>,
    mode: ExecMode,
    replication: usize,
    send_threads: usize,
    bind: String,
    worker_bin: Option<PathBuf>,
    delay: Option<(CostModel, u64, f64)>,
    node_delays: Vec<(usize, CostModel)>,
    pool: Option<String>,
}

impl CommBuilder {
    /// A communicator over the butterfly degree schedule `degrees`
    /// (logical node count = product). Defaults: lockstep mode, no
    /// replication, 4 sender threads.
    pub fn new(degrees: Vec<usize>) -> CommBuilder {
        CommBuilder {
            degrees,
            mode: ExecMode::Lockstep,
            replication: 1,
            send_threads: 4,
            bind: "127.0.0.1:0".to_string(),
            worker_bin: None,
            delay: None,
            node_delays: Vec::new(),
            pool: None,
        }
    }

    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Replicas per logical node (multi-process only; §V failover).
    pub fn replication(mut self, r: usize) -> Self {
        self.replication = r;
        self
    }

    pub fn send_threads(mut self, t: usize) -> Self {
        self.send_threads = t.max(1);
        self
    }

    /// Control-plane bind address for multi-process pools.
    pub fn bind(mut self, addr: impl Into<String>) -> Self {
        self.bind = addr.into();
        self
    }

    /// The `sar` binary to fork pool workers from (multi-process only;
    /// defaults to `$SAR_BIN` / the current executable).
    pub fn worker_binary(mut self, bin: PathBuf) -> Self {
        self.worker_bin = Some(bin);
        self
    }

    /// Connect to a separately launched worker pool (`sar serve`'s
    /// client address) instead of spawning one: the session's
    /// `configure`/`allreduce` run remotely against the pool's generic
    /// collective engine. Implies [`ExecMode::MultiProcess`]. The serve
    /// plane is multi-tenant — up to its `--sessions` limit of clients
    /// share the pool concurrently (arrivals past it queue), so many
    /// builders may point at one pool at once.
    pub fn pool(mut self, addr: impl Into<String>) -> Self {
        self.pool = Some(addr.into());
        self
    }

    /// Inject the simnet cost model into a threaded session's transport
    /// (the Figure 7 latency-hiding setup): per-message delay from
    /// `cost`, scaled by `time_scale`.
    pub fn delay(mut self, cost: CostModel, seed: u64, time_scale: f64) -> Self {
        self.delay = Some((cost, seed, time_scale));
        self
    }

    /// Override the injected cost model for messages sent BY `node`,
    /// on top of [`CommBuilder::delay`]'s base model: a heterogeneous
    /// pool with one slow host, deterministically — the elastic
    /// control plane's re-plan bench setup.
    pub fn delay_node(mut self, node: usize, cost: CostModel) -> Self {
        self.node_delays.push((node, cost));
        self
    }

    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Logical (protocol) node count.
    pub fn logical(&self) -> usize {
        self.degrees.iter().product()
    }

    pub fn degrees(&self) -> &[usize] {
        &self.degrees
    }

    pub fn send_threads_value(&self) -> usize {
        self.send_threads
    }

    fn validate(&self) -> Result<()> {
        validate_world(&self.degrees, self.replication, self.logical() * self.replication)?;
        if self.replication > 1 && self.mode != ExecMode::MultiProcess {
            bail!(
                "replication only applies to multi-process sessions (the in-process \
                 modes run the plain protocol)"
            );
        }
        if self.delay.is_some() && self.mode != ExecMode::Threaded {
            bail!("cost-model delay injection needs the threaded mode");
        }
        if !self.node_delays.is_empty() && self.delay.is_none() {
            bail!("per-node delay overrides need a base model: call .delay(...) first");
        }
        if self.pool.is_some() {
            if self.mode != ExecMode::MultiProcess {
                bail!(
                    "a pool address connects to a remote worker pool; it needs the \
                     multi-process mode (mp)"
                );
            }
            if self.replication > 1 {
                bail!(
                    "a pool's replication is fixed when it is launched; drop the \
                     client-side replication"
                );
            }
        }
        Ok(())
    }

    /// Spawn a worker pool whose pre-fork validation covers `jobs`
    /// (a bad schedule or shard dir must not cost a fleet of forked
    /// subprocesses).
    fn build_pool(self, jobs: Vec<JobSpec>) -> Result<Session> {
        let opts = crate::cluster::LaunchOpts {
            degrees: self.degrees.clone(),
            replication: self.replication,
            send_threads: self.send_threads,
            bind: self.bind.clone(),
            jobs,
            ..crate::cluster::LaunchOpts::default()
        };
        let bin = match &self.worker_bin {
            Some(b) => b.clone(),
            None => crate::cluster::sar_binary()?,
        };
        let (session, procs) =
            crate::cluster::spawn_session(&bin, opts).context("spawning the worker pool")?;
        Ok(Session::new_pool(
            self.degrees,
            self.send_threads,
            PoolBackend { session, procs: Some(procs) },
        ))
    }

    /// Open the communicator session. For the in-process modes
    /// `index_range` is the allreduce index domain `[0, index_range)`
    /// the session's butterfly covers; a locally spawned multi-process
    /// pool ignores it (each job descriptor carries its own domain) —
    /// the pool's workers are spawned now and JOIN before this returns.
    /// With a [`CommBuilder::pool`] address the session instead
    /// connects to the `sar serve`d pool and the raw two-phase
    /// lifecycle runs remotely over `index_range`.
    pub fn build(self, index_range: i64) -> Result<Session> {
        self.validate()?;
        match self.mode {
            ExecMode::Lockstep | ExecMode::Threaded => Session::new_in_process(
                self.mode,
                self.degrees,
                self.send_threads,
                index_range,
                self.delay,
                &self.node_delays,
            ),
            ExecMode::MultiProcess => match &self.pool {
                Some(addr) => {
                    if index_range < 1 {
                        bail!("index range must be >= 1 (got {index_range})");
                    }
                    let remote = RemoteSession::connect(addr, self.send_threads)?;
                    if remote.degrees() != self.degrees.as_slice() {
                        bail!(
                            "pool at {addr} runs schedule {:?} but this communicator \
                             wants {:?} — pass degrees matching the pool",
                            remote.degrees(),
                            self.degrees
                        );
                    }
                    Ok(Session::new_remote(
                        self.degrees,
                        self.send_threads,
                        index_range,
                        remote,
                    ))
                }
                None => self.build_pool(Vec::new()),
            },
        }
    }

    /// One-shot job run: build a session for exactly this job, run it,
    /// release it. In-process modes derive the index domain from the
    /// job's prepared dataset; a multi-process submit spawns a worker
    /// pool — validated against THIS job (schedule, shard dir) before
    /// any process is forked — ships the job descriptor, and shuts the
    /// pool down after the report. With a [`CommBuilder::pool`] address
    /// no job descriptor crosses the wire at all: the job's driver runs
    /// here and its collectives run remotely, so even apps the pool has
    /// never heard of execute distributed.
    pub fn submit(&self, spec: &JobSpec) -> Result<JobOutcome> {
        spec.validate()?;
        match self.mode {
            ExecMode::MultiProcess if self.pool.is_none() => {
                let me = self.clone();
                me.validate()?;
                let mut sess = me.build_pool(vec![spec.clone()])?;
                sess.submit(spec)
            }
            _ => run::run_in_process(self, spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_shape() {
        assert!(CommBuilder::new(vec![2, 2]).build(16).is_ok());
        assert!(CommBuilder::new(vec![]).build(16).is_err());
        assert!(CommBuilder::new(vec![2, 0]).build(16).is_err());
        // replication needs multi-process
        assert!(CommBuilder::new(vec![2]).replication(2).build(16).is_err());
        // delay injection needs threaded
        let err = CommBuilder::new(vec![2])
            .delay(CostModel::ideal(1e9), 1, 1.0)
            .build(16)
            .unwrap_err();
        assert!(format!("{err:#}").contains("threaded"), "got {err:#}");
        // a per-node override without a base model is a readable error
        let err = CommBuilder::new(vec![2])
            .mode(ExecMode::Threaded)
            .delay_node(1, CostModel::ideal(1e9))
            .build(16)
            .unwrap_err();
        assert!(format!("{err:#}").contains("base model"), "got {err:#}");
        // in-process sessions need a positive index range
        assert!(CommBuilder::new(vec![2]).build(0).is_err());
    }

    #[test]
    fn pool_address_validation() {
        // a pool address without the multi-process mode is a readable error
        let err = CommBuilder::new(vec![2, 2]).pool("127.0.0.1:7431").build(16).unwrap_err();
        assert!(format!("{err:#}").contains("multi-process"), "got {err:#}");
        // client-side replication contradicts a launched pool
        let err = CommBuilder::new(vec![2, 2])
            .mode(ExecMode::MultiProcess)
            .pool("127.0.0.1:7431")
            .replication(2)
            .build(16)
            .unwrap_err();
        assert!(format!("{err:#}").contains("replication"), "got {err:#}");
    }
}
