//! Mode-agnostic job execution: drive a [`JobSpec`] through a
//! communicator [`Session`].
//!
//! The in-process paths prepare the app's per-node engines
//! (`apps::{pagerank,diameter,sgd}`) and loop configure/allreduce on the
//! session — ONE driver per app, shared by lockstep and threaded (the
//! session hides the difference). The multi-process path hands the spec
//! to the worker pool, whose workers run the *same* per-node engines
//! against their transport-backed handles, so the reported checksum is
//! comparable across all three modes.

use super::builder::CommBuilder;
use super::job::{AppKind, JobOutcome, JobSpec, SGD_ZIPF_ALPHA};
use super::session::Session;
use crate::apps::diameter::{diameter_checksum, DiameterConfig, DiameterNode};
use crate::apps::pagerank::{self, PageRankShards};
use crate::apps::sgd::{sgd_step, NativeGradEngine, SgdConfig, SgdNode, SynthData};
use crate::graph::{Csr, DatasetPreset, DatasetSpec};
use crate::obs::RunMetrics;
use crate::sparse::{IndexSet, OrU32, SumF32};
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A job's prepared per-node state, ready to drive through a session.
pub(crate) enum Prepared {
    Pagerank { shards: Vec<Csr>, vertices: i64 },
    Diameter { nodes: Vec<DiameterNode> },
    Sgd { nodes: Vec<SgdNode<NativeGradEngine>> },
}

impl Prepared {
    /// The allreduce index domain the job's collective runs over.
    pub(crate) fn index_range(&self) -> i64 {
        match self {
            Prepared::Pagerank { vertices, .. } => *vertices,
            Prepared::Diameter { nodes } => nodes[0].index_range(),
            Prepared::Sgd { nodes } => nodes[0].index_range(),
        }
    }
}

/// Build the job's per-node engines for an `m`-lane communicator.
pub(crate) fn prepare(spec: &JobSpec, m: usize) -> Result<Prepared> {
    match spec.app {
        AppKind::Pagerank => {
            if let Some(dir) = &spec.shards {
                let (manifest, shards) = crate::graph::load_all_shards(dir)
                    .with_context(|| format!("loading shards from {}", dir.display()))?;
                manifest.check_run_identity(&spec.dataset, spec.scale, spec.seed)?;
                if shards.len() != m {
                    bail!(
                        "shard dir {} holds {} shards but the schedule covers {m} \
                         logical nodes",
                        dir.display(),
                        shards.len()
                    );
                }
                Ok(Prepared::Pagerank { shards, vertices: manifest.vertices })
            } else {
                let preset = DatasetPreset::by_name(&spec.dataset).ok_or_else(|| {
                    anyhow::anyhow!("unknown dataset `{}` (twitter|yahoo|docterm)", spec.dataset)
                })?;
                let graph = DatasetSpec::new(preset, spec.scale, spec.seed).generate();
                let built = PageRankShards::build(&graph, m, spec.seed);
                Ok(Prepared::Pagerank { shards: built.shards, vertices: graph.vertices })
            }
        }
        AppKind::Diameter => {
            let preset = DatasetPreset::by_name(&spec.dataset).ok_or_else(|| {
                anyhow::anyhow!("unknown dataset `{}` (twitter|yahoo|docterm)", spec.dataset)
            })?;
            let graph = DatasetSpec::new(preset, spec.scale, spec.seed).generate();
            let cfg = DiameterConfig {
                k_sketches: spec.sketches,
                max_h: spec.iters,
                exact: false,
                seed: spec.seed,
            };
            Ok(Prepared::Diameter { nodes: DiameterNode::build_all(&graph, m, &cfg) })
        }
        AppKind::Sgd => {
            let data = Arc::new(SynthData::new(
                spec.features,
                spec.classes,
                spec.feats_per_ex,
                SGD_ZIPF_ALPHA,
            ));
            let cfg = SgdConfig {
                classes: spec.classes,
                batch_per_worker: spec.batch,
                lr: spec.lr,
                seed: spec.seed,
            };
            let nodes = (0..m)
                .map(|w| SgdNode::new(w, data.clone(), cfg, NativeGradEngine))
                .collect();
            Ok(Prepared::Sgd { nodes })
        }
    }
}

/// One-shot driver-side run: prepare the job, open a session of exactly
/// its index domain, drive it. The session may be in-process (lockstep,
/// threaded) or a remote-pool client (`CommBuilder::pool`) — the driver
/// code is identical; only where each lane's collective executes
/// differs.
pub(crate) fn run_in_process(builder: &CommBuilder, spec: &JobSpec) -> Result<JobOutcome> {
    let prepared = prepare(spec, builder.logical())?;
    let mut session = builder.clone().build(prepared.index_range())?;
    drive(&mut session, spec, prepared)
}

fn drive(session: &mut Session, spec: &JobSpec, prepared: Prepared) -> Result<JobOutcome> {
    match prepared {
        Prepared::Pagerank { shards, vertices } => drive_pagerank(session, spec, shards, vertices),
        Prepared::Diameter { nodes } => drive_diameter(session, spec, nodes),
        Prepared::Sgd { nodes } => drive_sgd(session, spec, nodes),
    }
}

fn outcome(spec: &JobSpec, checksum: f64, wall_secs: f64, config_secs: f64) -> JobOutcome {
    JobOutcome {
        job: spec.name.clone(),
        app: spec.app,
        checksum,
        wall_secs,
        config_secs,
        per_node: Vec::new(),
        losses: Vec::new(),
        neighbourhood: Vec::new(),
        dead: Vec::new(),
    }
}

/// One lane's PageRank state, owned by the lane closures so a threaded
/// session runs the SpMV and the score update ON the lane threads (in
/// parallel across lanes) instead of serially on the driver — the
/// ROADMAP PR 4 follow-up. The `Arc` makes moving the CSR between the
/// driver and the lane threads a pointer copy.
struct PrLane {
    shard: Arc<Csr>,
    p: Vec<f32>,
}

fn drive_pagerank(
    session: &mut Session,
    spec: &JobSpec,
    shards: Vec<Csr>,
    vertices: i64,
) -> Result<JobOutcome> {
    let m = shards.len();
    let t0 = Instant::now();
    let outbound: Vec<IndexSet> =
        shards.iter().map(|s| IndexSet::from_sorted(s.row_globals.clone())).collect();
    let inbound: Vec<IndexSet> =
        shards.iter().map(|s| IndexSet::from_sorted(s.col_globals.clone())).collect();
    let mut handle = session.configure(outbound, inbound)?;
    let config_secs = t0.elapsed().as_secs_f64();

    let mut metrics: Vec<RunMetrics> = (0..m).map(|_| RunMetrics::new()).collect();
    for mtr in &mut metrics {
        mtr.config_secs = config_secs;
    }
    let mut lanes: Vec<PrLane> = shards
        .into_iter()
        .map(|s| {
            let p = pagerank::initial_p(vertices, s.cols());
            PrLane { shard: Arc::new(s), p }
        })
        .collect();
    let wall = Instant::now();
    for _ in 0..spec.iters {
        let results = handle.allreduce_compute::<SumF32, PrLane, _, _>(
            lanes,
            |_, lane| lane.shard.spmv(&lane.p),
            move |_, lane, sums| pagerank::apply_update(&mut lane.p, &sums, vertices),
        )?;
        lanes = Vec::with_capacity(m);
        for (n, (lane, compute, comm)) in results.into_iter().enumerate() {
            metrics[n]
                .push(Duration::from_secs_f64(compute), Duration::from_secs_f64(comm));
            lanes.push(lane);
        }
    }
    let wall_secs = wall.elapsed().as_secs_f64();
    let checksum: f64 =
        lanes.iter().map(|l| l.p.first().copied().unwrap_or(0.0) as f64).sum();
    let mut out = outcome(spec, checksum, wall_secs, config_secs);
    out.per_node = metrics;
    Ok(out)
}

fn drive_diameter(
    session: &mut Session,
    spec: &JobSpec,
    mut nodes: Vec<DiameterNode>,
) -> Result<JobOutcome> {
    let t0 = Instant::now();
    let sets: Vec<IndexSet> = nodes.iter().map(|n| n.index_set()).collect();
    let mut handle = session.configure(sets.clone(), sets)?;
    let config_secs = t0.elapsed().as_secs_f64();

    let mut neighbourhood = Vec::with_capacity(spec.iters);
    let wall = Instant::now();
    for _ in 0..spec.iters {
        let mut vals: Vec<Vec<u32>> = nodes.iter().map(|n| n.contribution()).collect();
        handle.allreduce::<OrU32>(&mut vals)?;
        for (node, v) in nodes.iter_mut().zip(vals) {
            node.absorb(v);
        }
        neighbourhood.push(nodes[0].neighbourhood_estimate());
    }
    let wall_secs = wall.elapsed().as_secs_f64();
    let mut out = outcome(spec, diameter_checksum(&nodes), wall_secs, config_secs);
    out.neighbourhood = neighbourhood;
    Ok(out)
}

fn drive_sgd(
    session: &mut Session,
    spec: &JobSpec,
    mut nodes: Vec<SgdNode<NativeGradEngine>>,
) -> Result<JobOutcome> {
    let mut losses = Vec::with_capacity(spec.iters);
    let wall = Instant::now();
    for _ in 0..spec.iters {
        losses.push(sgd_step(session, &mut nodes)?);
    }
    let wall_secs = wall.elapsed().as_secs_f64();
    let checksum: f64 = nodes.iter().map(|n| n.final_loss() as f64).sum();
    let mut out = outcome(spec, checksum, wall_secs, 0.0);
    out.losses = losses;
    Ok(out)
}

fn outcome_from_cluster(spec: &JobSpec, run: &crate::cluster::ClusterRun) -> JobOutcome {
    JobOutcome {
        job: spec.name.clone(),
        app: spec.app,
        checksum: run.checksum,
        wall_secs: run.wall_secs,
        config_secs: run.config_secs,
        per_node: run.per_node.iter().flatten().cloned().collect(),
        losses: Vec::new(),
        neighbourhood: Vec::new(),
        dead: run.dead.clone(),
    }
}

impl Session {
    /// Run a whole application job on this communicator.
    ///
    /// * In-process sessions drive the app's per-node engines through
    ///   their own configure/allreduce lifecycle; the job's index
    ///   domain must match the domain the session was built over.
    /// * Pool sessions ship the descriptor to the JOINed workers — a
    ///   per-job CONFIG/START/REPORT cycle on the long-lived pool, so
    ///   consecutive `submit` calls reuse the same worker processes.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobOutcome> {
        spec.validate()?;
        if let Some(pool) = self.pool_mut() {
            let run = pool.session.run_job(spec)?;
            return Ok(outcome_from_cluster(spec, &run));
        }
        let prepared = prepare(spec, self.lanes())?;
        if prepared.index_range() != self.index_range() {
            bail!(
                "job `{}` needs index domain {} but this session was built over {} — \
                 open one with CommBuilder::build({}) or use CommBuilder::submit",
                spec.name,
                prepared.index_range(),
                self.index_range(),
                prepared.index_range()
            );
        }
        drive(self, spec, prepared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pagerank() -> JobSpec {
        JobSpec { scale: 0.002, iters: 4, ..JobSpec::pagerank() }
    }

    #[test]
    fn lockstep_submit_matches_dist_pagerank_oracle() {
        let spec = tiny_pagerank();
        let preset = DatasetPreset::by_name(&spec.dataset).unwrap();
        let graph = DatasetSpec::new(preset, spec.scale, spec.seed).generate();
        let mut oracle = crate::apps::pagerank::DistPageRank::new(
            &graph,
            vec![2, 2],
            &crate::apps::pagerank::PageRankConfig { seed: spec.seed, iters: spec.iters },
        );
        oracle.run(spec.iters);

        let out = CommBuilder::new(vec![2, 2]).submit(&spec).unwrap();
        assert_eq!(out.checksum, oracle.checksum(), "session must reproduce the oracle");
        assert_eq!(out.per_node.len(), 4);
        assert!(out.wall_secs >= 0.0);
    }

    #[test]
    fn session_reuse_across_jobs_with_matching_domain() {
        let spec = tiny_pagerank();
        let prepared = prepare(&spec, 4).unwrap();
        let range = prepared.index_range();
        let mut sess = CommBuilder::new(vec![2, 2]).build(range).unwrap();
        let a = sess.submit(&spec).unwrap();
        let b = sess.submit(&spec).unwrap();
        assert_eq!(a.checksum, b.checksum, "same job on a reused session");
        // a mismatched domain is a readable error, not a wrong answer
        let other = JobSpec::sgd();
        let err = sess.submit(&other).unwrap_err();
        assert!(format!("{err:#}").contains("index domain"), "got {err:#}");
    }
}
