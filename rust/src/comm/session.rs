//! The communicator session: one handle over the paper's two-phase
//! lifecycle, backed by any execution mode.
//!
//! In-process backends:
//!
//! * **Lockstep** wraps [`LocalCluster`] — the deterministic
//!   single-thread oracle.
//! * **Threaded** keeps one long-lived worker thread per logical node,
//!   each owning a [`NodeHandle`] over a shared in-process transport.
//!   `configure`/`allreduce` ship one closure per lane down a channel
//!   and collect the per-lane results, so repeated collectives reuse
//!   the same threads (and the same transport) instead of re-spawning a
//!   cluster per call.
//!
//! The multi-process backend holds a planned [`crate::cluster::Session`]
//! worker pool (plus the locally-forked worker processes when the pool
//! was spawned rather than joined); whole jobs are submitted to it via
//! [`Session::submit`], and the raw `configure`/`allreduce` door returns
//! a readable error — per-iteration values never cross the control
//! plane.

use super::ExecMode;
use crate::allreduce::threaded::NodeHandle;
use crate::allreduce::LocalCluster;
use crate::simnet::CostModel;
use crate::sparse::{IndexSet, ReduceOp};
use crate::topology::Butterfly;
use crate::transport::{DelayTransport, Envelope, MemTransport, Transport, TransportError};
use anyhow::{bail, Context, Result};
use std::any::Any;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// The in-process transport a threaded session runs on: plain shared
/// memory, or the same wrapped in the simnet cost model (the Figure 7
/// latency-hiding study's setup).
pub(crate) enum LaneTransport {
    Mem(MemTransport),
    Delay(DelayTransport<MemTransport>),
}

impl Transport for LaneTransport {
    fn machines(&self) -> usize {
        match self {
            LaneTransport::Mem(t) => t.machines(),
            LaneTransport::Delay(t) => t.machines(),
        }
    }

    fn send(&self, dst: crate::topology::NodeId, env: Envelope) -> Result<(), TransportError> {
        match self {
            LaneTransport::Mem(t) => t.send(dst, env),
            LaneTransport::Delay(t) => t.send(dst, env),
        }
    }

    fn recv(&self, node: crate::topology::NodeId, timeout: Duration) -> Result<Envelope, TransportError> {
        match self {
            LaneTransport::Mem(t) => t.recv(node, timeout),
            LaneTransport::Delay(t) => t.recv(node, timeout),
        }
    }
}

type LaneResult = Box<dyn Any + Send>;
/// A lane's answer: the closure's boxed result, or the panic payload if
/// the closure unwound (a lane panic must surface on the driver thread,
/// not hang `run_all` waiting for a result that will never come).
type LaneOutcome = std::thread::Result<LaneResult>;
type LaneCmd = Box<dyn FnOnce(&mut NodeHandle<LaneTransport>) -> LaneResult + Send>;

/// Persistent per-node worker threads for the threaded backend.
struct ThreadedLanes {
    cmds: Vec<Sender<LaneCmd>>,
    results: Receiver<(usize, LaneOutcome)>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadedLanes {
    fn spawn(topo: &Butterfly, transport: Arc<LaneTransport>, send_threads: usize) -> ThreadedLanes {
        let m = topo.machines();
        let (res_tx, results) = channel();
        let mut cmds = Vec::with_capacity(m);
        let mut threads = Vec::with_capacity(m);
        for node in 0..m {
            let (tx, rx) = channel::<LaneCmd>();
            cmds.push(tx);
            let topo = topo.clone();
            let transport = transport.clone();
            let res_tx = res_tx.clone();
            threads.push(std::thread::spawn(move || {
                let mut h = NodeHandle::new(topo, node, transport, send_threads);
                while let Ok(cmd) = rx.recv() {
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        cmd(&mut h)
                    }));
                    let panicked = out.is_err();
                    if res_tx.send((node, out)).is_err() {
                        return;
                    }
                    if panicked {
                        // The handle's protocol state is unknown after an
                        // unwind; retire the lane (the driver re-raises).
                        return;
                    }
                }
            }));
        }
        ThreadedLanes { cmds, results, threads }
    }

    /// Run one closure per lane concurrently; results in lane order.
    /// Session methods are serialized on `&mut self`, so every received
    /// result belongs to this batch. A lane panic is re-raised here.
    fn run_all<O, F>(&self, fns: Vec<F>) -> Vec<O>
    where
        O: Send + 'static,
        F: FnOnce(&mut NodeHandle<LaneTransport>) -> O + Send + 'static,
    {
        assert_eq!(fns.len(), self.cmds.len(), "one closure per lane");
        for (tx, f) in self.cmds.iter().zip(fns) {
            let cmd: LaneCmd = Box::new(move |h| Box::new(f(h)) as LaneResult);
            tx.send(cmd).expect("lane thread exited early");
        }
        let mut out: Vec<Option<O>> = (0..self.cmds.len()).map(|_| None).collect();
        for _ in 0..self.cmds.len() {
            let (node, r) = self.results.recv().expect("lane thread gone without reporting");
            match r {
                Ok(v) => out[node] = Some(*v.downcast::<O>().expect("lane result type")),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out.into_iter().map(|o| o.expect("one result per lane")).collect()
    }
}

impl Drop for ThreadedLanes {
    fn drop(&mut self) {
        // Disconnect the command channels so every lane thread's recv
        // errors and the thread exits, then reap.
        self.cmds.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// A planned multi-process worker pool plus (when locally spawned) the
/// worker subprocesses backing it.
pub(crate) struct PoolBackend {
    pub(crate) session: crate::cluster::Session,
    pub(crate) procs: Option<crate::cluster::LocalProcs>,
}

impl Drop for PoolBackend {
    fn drop(&mut self) {
        // Release the pool before reaping, so locally-spawned workers
        // exit on SHUTDOWN instead of being killed mid-frame.
        self.session.shutdown();
        if let Some(procs) = &mut self.procs {
            procs.wait_all();
        }
    }
}

enum Backend {
    Lockstep(LocalCluster),
    Threaded(ThreadedLanes),
    Pool(Box<PoolBackend>),
}

/// One communicator handle (see module docs for the lifecycle).
pub struct Session {
    mode: ExecMode,
    degrees: Vec<usize>,
    send_threads: usize,
    index_range: i64,
    configured: bool,
    out_lens: Vec<usize>,
    in_lens: Vec<usize>,
    backend: Backend,
}

impl Session {
    /// Build an in-process session (lockstep or threaded lanes).
    pub(crate) fn new_in_process(
        mode: ExecMode,
        degrees: Vec<usize>,
        send_threads: usize,
        index_range: i64,
        delay: Option<(CostModel, u64, f64)>,
    ) -> Result<Session> {
        if index_range < 1 {
            bail!("index range must be >= 1 (got {index_range})");
        }
        let topo = Butterfly::new(degrees.clone(), index_range);
        let m = topo.machines();
        let backend = match mode {
            ExecMode::Lockstep => {
                if delay.is_some() {
                    bail!("cost-model delay injection needs --mode threaded");
                }
                Backend::Lockstep(LocalCluster::new(topo))
            }
            ExecMode::Threaded => {
                let transport = match delay {
                    None => LaneTransport::Mem(MemTransport::new(m)),
                    Some((cost, seed, scale)) => LaneTransport::Delay(
                        DelayTransport::new(MemTransport::new(m), cost, seed)
                            .with_time_scale(scale),
                    ),
                };
                Backend::Threaded(ThreadedLanes::spawn(&topo, Arc::new(transport), send_threads))
            }
            ExecMode::MultiProcess => {
                bail!("multi-process sessions are built from a worker pool (CommBuilder)")
            }
        };
        Ok(Session {
            mode,
            degrees,
            send_threads,
            index_range,
            configured: false,
            out_lens: Vec::new(),
            in_lens: Vec::new(),
            backend,
        })
    }

    /// Wrap a planned worker pool as a session (jobs only).
    pub(crate) fn new_pool(degrees: Vec<usize>, send_threads: usize, pool: PoolBackend) -> Session {
        Session {
            mode: ExecMode::MultiProcess,
            degrees,
            send_threads,
            index_range: 0,
            configured: false,
            out_lens: Vec::new(),
            in_lens: Vec::new(),
            backend: Backend::Pool(Box::new(pool)),
        }
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn degrees(&self) -> &[usize] {
        &self.degrees
    }

    /// Logical lanes (= butterfly machine count).
    pub fn lanes(&self) -> usize {
        self.degrees.iter().product()
    }

    pub fn send_threads(&self) -> usize {
        self.send_threads
    }

    /// The allreduce index domain this session was built over (0 for a
    /// worker pool, whose jobs each carry their own domain).
    pub fn index_range(&self) -> i64 {
        self.index_range
    }

    pub(crate) fn pool_mut(&mut self) -> Option<&mut PoolBackend> {
        match &mut self.backend {
            Backend::Pool(p) => Some(p),
            _ => None,
        }
    }

    /// Run the config phase once for a sparsity pattern: `outbound[n]` /
    /// `inbound[n]` are lane `n`'s contributed / requested index sets.
    /// The returned handle borrows the session; reconfiguring (a new
    /// sparsity pattern, e.g. SGD's per-step feature sets) just means
    /// calling `configure` again once the handle is dropped.
    pub fn configure(
        &mut self,
        outbound: Vec<IndexSet>,
        inbound: Vec<IndexSet>,
    ) -> Result<ConfigHandle<'_>> {
        let m = self.lanes();
        if outbound.len() != m || inbound.len() != m {
            bail!(
                "configure needs one outbound and one inbound set per lane \
                 ({m} lanes, got {} outbound / {} inbound)",
                outbound.len(),
                inbound.len()
            );
        }
        self.out_lens = outbound.iter().map(|s| s.len()).collect();
        self.in_lens = inbound.iter().map(|s| s.len()).collect();
        match &mut self.backend {
            Backend::Lockstep(cluster) => {
                cluster.config(outbound, inbound);
            }
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = outbound
                    .into_iter()
                    .zip(inbound)
                    .map(|(o, i)| {
                        move |h: &mut NodeHandle<LaneTransport>| h.config(o, i)
                    })
                    .collect();
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    r.with_context(|| format!("lane {n} config failed"))?;
                }
            }
            Backend::Pool(_) => bail!(
                "a multi-process pool session runs whole jobs (Session::submit / \
                 `sar launch --jobs`); per-iteration values never cross the control plane"
            ),
        }
        self.configured = true;
        Ok(ConfigHandle { sess: self })
    }

    fn check_values<T>(&self, values: &[Vec<T>]) -> Result<()> {
        if !self.configured {
            bail!("allreduce before configure");
        }
        if values.len() != self.lanes() {
            bail!("allreduce needs one value vector per lane ({} lanes, got {})",
                  self.lanes(), values.len());
        }
        for (n, (v, &want)) in values.iter().zip(&self.out_lens).enumerate() {
            if v.len() != want {
                bail!(
                    "lane {n}: {} values but the configured outbound set has {want} \
                     indices (reconfigure for a new sparsity pattern)",
                    v.len()
                );
            }
        }
        Ok(())
    }

    fn allreduce_impl<R: ReduceOp>(&mut self, values: &mut Vec<Vec<R::T>>) -> Result<()> {
        self.check_values(&*values)?;
        let input = std::mem::take(values);
        let reduced = match &mut self.backend {
            Backend::Lockstep(cluster) => cluster.reduce::<R>(input).0,
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = input
                    .into_iter()
                    .map(|v| move |h: &mut NodeHandle<LaneTransport>| h.reduce::<R>(v))
                    .collect();
                let mut out = Vec::with_capacity(self.out_lens.len());
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    out.push(r.with_context(|| format!("lane {n} reduce failed"))?);
                }
                out
            }
            Backend::Pool(_) => bail!("pool sessions run jobs, not raw collectives"),
        };
        *values = reduced;
        Ok(())
    }

    fn allreduce_with_bottom_impl<R, F>(
        &mut self,
        values: Vec<Vec<R::T>>,
        bottoms: Vec<F>,
    ) -> Result<Vec<Vec<R::T>>>
    where
        R: ReduceOp,
        F: FnOnce(&IndexSet, &[R::T], &IndexSet) -> Vec<R::T> + Send + 'static,
    {
        self.check_values(&values)?;
        if bottoms.len() != self.lanes() {
            bail!("one bottom transform per lane required");
        }
        match &mut self.backend {
            Backend::Lockstep(cluster) => {
                let cluster: &LocalCluster = cluster;
                let mut slots: Vec<Option<F>> = bottoms.into_iter().map(Some).collect();
                let (got, _trace) = cluster.reduce_with_bottom::<R, _>(values, |node, reduced| {
                    let f = slots[node].take().expect("bottom transform runs once per lane");
                    let p = cluster.node(node);
                    f(p.bottom_down_set(), reduced, p.bottom_up_set())
                });
                Ok(got)
            }
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = values
                    .into_iter()
                    .zip(bottoms)
                    .map(|(v, f)| {
                        move |h: &mut NodeHandle<LaneTransport>| h.reduce_with_bottom::<R, F>(v, f)
                    })
                    .collect();
                let mut out = Vec::with_capacity(self.out_lens.len());
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    out.push(r.with_context(|| format!("lane {n} reduce failed"))?);
                }
                Ok(out)
            }
            Backend::Pool(_) => bail!("pool sessions run jobs, not raw collectives"),
        }
    }
}

/// Proof that the config phase ran; the door to the reduce phase.
pub struct ConfigHandle<'s> {
    sess: &'s mut Session,
}

impl ConfigHandle<'_> {
    pub fn lanes(&self) -> usize {
        self.sess.lanes()
    }

    /// One sparse allreduce: `values[n]` aligned with lane `n`'s
    /// configured outbound set going in, replaced by the reduced values
    /// aligned with its inbound set coming out. Generic over the reduce
    /// operator — `SumF32`, `OrU32` and `MaxF32` all take this one path.
    pub fn allreduce<R: ReduceOp>(&mut self, values: &mut Vec<Vec<R::T>>) -> Result<()> {
        self.sess.allreduce_impl::<R>(values)
    }

    /// Allreduce with a custom bottom-of-butterfly transform per lane:
    /// after the scatter-reduce, `bottoms[n](down_set, reduced, up_set)`
    /// receives lane `n`'s fully-reduced bottom range and must return
    /// one value per `up_set` index to be allgathered. This is the
    /// parameter-server mode of the paper's mini-batch SGD (§III-B):
    /// the bottom owner folds gradients into its persistent model shard
    /// and serves fresh weights back up.
    pub fn allreduce_with_bottom<R, F>(
        &mut self,
        values: Vec<Vec<R::T>>,
        bottoms: Vec<F>,
    ) -> Result<Vec<Vec<R::T>>>
    where
        R: ReduceOp,
        F: FnOnce(&IndexSet, &[R::T], &IndexSet) -> Vec<R::T> + Send + 'static,
    {
        self.sess.allreduce_with_bottom_impl::<R, F>(values, bottoms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{MaxF32, OrU32, SumF32};

    fn sets(v: Vec<Vec<i64>>) -> Vec<IndexSet> {
        v.into_iter().map(IndexSet::from_unsorted).collect()
    }

    fn session(mode: ExecMode) -> Session {
        Session::new_in_process(mode, vec![2, 2], 2, 64, None).unwrap()
    }

    fn check_sum_session(mut s: Session) {
        let out = sets(vec![vec![1, 5], vec![5, 9], vec![2], vec![]]);
        let inb = sets(vec![vec![5], vec![1, 2], vec![9], vec![5, 9]]);
        let mut cfg = s.configure(out, inb).unwrap();
        let mut vals = vec![vec![1.0f32, 10.0], vec![20.0, 3.0], vec![7.0], vec![]];
        cfg.allreduce::<SumF32>(&mut vals).unwrap();
        assert_eq!(vals[0], vec![30.0]);
        assert_eq!(vals[1], vec![1.0, 7.0]);
        assert_eq!(vals[2], vec![3.0]);
        assert_eq!(vals[3], vec![30.0, 3.0]);
        // same config, second reduce (values doubled)
        let mut vals = vec![vec![2.0f32, 20.0], vec![40.0, 6.0], vec![14.0], vec![]];
        cfg.allreduce::<SumF32>(&mut vals).unwrap();
        assert_eq!(vals[0], vec![60.0]);
    }

    #[test]
    fn lockstep_session_reduces_and_reuses_config() {
        check_sum_session(session(ExecMode::Lockstep));
    }

    #[test]
    fn threaded_session_reduces_and_reuses_config() {
        check_sum_session(session(ExecMode::Threaded));
    }

    #[test]
    fn or_and_max_flow_through_the_same_path() {
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![3], vec![3], vec![7], vec![]]);
            let inb = sets(vec![vec![3, 7], vec![3], vec![3], vec![7]]);
            let mut cfg = s.configure(out.clone(), inb.clone()).unwrap();
            let mut vals = vec![vec![0b01u32], vec![0b10], vec![0b100], vec![]];
            cfg.allreduce::<OrU32>(&mut vals).unwrap();
            assert_eq!(vals[0], vec![0b11, 0b100], "{mode:?}");
            assert_eq!(vals[3], vec![0b100], "{mode:?}");
            drop(cfg);
            let mut cfg = s.configure(out, inb).unwrap();
            let mut vals = vec![vec![2.0f32], vec![5.0], vec![-1.0], vec![]];
            cfg.allreduce::<MaxF32>(&mut vals).unwrap();
            assert_eq!(vals[0], vec![5.0, -1.0], "{mode:?}");
            assert_eq!(vals[1], vec![5.0], "{mode:?}");
        }
    }

    #[test]
    fn misuse_is_a_readable_error() {
        let mut s = session(ExecMode::Lockstep);
        // allreduce before configure
        let mut vals: Vec<Vec<f32>> = vec![vec![]; 4];
        assert!(s.allreduce_impl::<SumF32>(&mut vals).is_err());
        // wrong lane count
        assert!(s.configure(sets(vec![vec![]]), sets(vec![vec![]])).is_err());
        // wrong value length vs configured outbound
        let out = sets(vec![vec![1], vec![], vec![], vec![]]);
        let inb = sets(vec![vec![1], vec![], vec![], vec![]]);
        let mut cfg = s.configure(out, inb).unwrap();
        let mut vals = vec![vec![1.0f32, 2.0], vec![], vec![], vec![]];
        let err = cfg.allreduce::<SumF32>(&mut vals).unwrap_err();
        assert!(format!("{err:#}").contains("outbound set"), "got {err:#}");
    }

    #[test]
    fn bottom_transform_runs_per_lane() {
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![1], vec![1], vec![], vec![]]);
            let inb = sets(vec![vec![1], vec![1], vec![1], vec![]]);
            let mut cfg = s.configure(out, inb).unwrap();
            // bottom transform: negate the reduced sums before gathering
            let bottoms: Vec<_> = (0..4)
                .map(|_| {
                    |down: &IndexSet, reduced: &[f32], up: &IndexSet| {
                        assert_eq!(down.len(), reduced.len());
                        up.as_slice()
                            .iter()
                            .map(|i| {
                                down.position(*i)
                                    .map(|p| -reduced[p])
                                    .unwrap_or(0.0)
                            })
                            .collect::<Vec<f32>>()
                    }
                })
                .collect();
            let got = cfg
                .allreduce_with_bottom::<SumF32, _>(
                    vec![vec![2.0], vec![3.0], vec![], vec![]],
                    bottoms,
                )
                .unwrap();
            assert_eq!(got[0], vec![-5.0], "{mode:?}");
            assert_eq!(got[1], vec![-5.0], "{mode:?}");
            assert_eq!(got[2], vec![-5.0], "{mode:?}");
            assert_eq!(got[3], Vec::<f32>::new(), "{mode:?}");
        }
    }
}
