//! The communicator session: one handle over the paper's two-phase
//! lifecycle, backed by any execution mode.
//!
//! In-process backends:
//!
//! * **Lockstep** wraps [`LocalCluster`] — the deterministic
//!   single-thread oracle.
//! * **Threaded** keeps one long-lived worker thread per logical node,
//!   each owning a [`NodeHandle`] over a shared in-process transport.
//!   `configure`/`allreduce` ship one closure per lane down a channel
//!   and collect the per-lane results, so repeated collectives reuse
//!   the same threads (and the same transport) instead of re-spawning a
//!   cluster per call.
//!
//! Multi-process backends come in two shapes:
//!
//! * **Pool** — a locally planned [`crate::cluster::Session`] worker
//!   pool (plus the forked worker processes when spawned rather than
//!   joined); whole jobs are submitted to it via [`Session::submit`],
//!   and the raw `configure`/`allreduce` door points at the remote
//!   plane instead.
//! * **Remote** — a [`RemoteSession`] client connection to a separately
//!   `sar serve`-launched pool (`CommBuilder::pool(addr)`): the raw
//!   two-phase lifecycle works exactly like the in-process modes, with
//!   each lane's collective executed by a pool worker and only index
//!   sets / sparse values crossing the ingress. The pool multiplexes
//!   sessions (see [`crate::cluster::mux`]), so several remote
//!   sessions — from one process or many — share it concurrently;
//!   dropping the session hands its slot to the next queued client.

use super::remote::RemoteSession;
use super::ExecMode;
use crate::allreduce::threaded::NodeHandle;
use crate::allreduce::LocalCluster;
use crate::simnet::CostModel;
use crate::sparse::{IndexSet, ReduceOp};
use crate::topology::Butterfly;
use crate::transport::{DelayTransport, Envelope, MemTransport, Transport, TransportError};
use anyhow::{bail, Context, Result};
use std::any::Any;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The in-process transport a threaded session runs on: plain shared
/// memory, or the same wrapped in the simnet cost model (the Figure 7
/// latency-hiding study's setup).
pub(crate) enum LaneTransport {
    Mem(MemTransport),
    Delay(DelayTransport<MemTransport>),
}

impl Transport for LaneTransport {
    fn machines(&self) -> usize {
        match self {
            LaneTransport::Mem(t) => t.machines(),
            LaneTransport::Delay(t) => t.machines(),
        }
    }

    fn send(&self, dst: crate::topology::NodeId, env: Envelope) -> Result<(), TransportError> {
        match self {
            LaneTransport::Mem(t) => t.send(dst, env),
            LaneTransport::Delay(t) => t.send(dst, env),
        }
    }

    fn recv(&self, node: crate::topology::NodeId, timeout: Duration) -> Result<Envelope, TransportError> {
        match self {
            LaneTransport::Mem(t) => t.recv(node, timeout),
            LaneTransport::Delay(t) => t.recv(node, timeout),
        }
    }
}

type LaneResult = Box<dyn Any + Send>;
/// A lane's answer: the closure's boxed result, or the panic payload if
/// the closure unwound (a lane panic must surface on the driver thread,
/// not hang `run_all` waiting for a result that will never come).
type LaneOutcome = std::thread::Result<LaneResult>;
type LaneCmd = Box<dyn FnOnce(&mut NodeHandle<LaneTransport>) -> LaneResult + Send>;

/// Persistent per-node worker threads for the threaded backend.
struct ThreadedLanes {
    cmds: Vec<Sender<LaneCmd>>,
    results: Receiver<(usize, LaneOutcome)>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadedLanes {
    fn spawn(topo: &Butterfly, transport: Arc<LaneTransport>, send_threads: usize) -> ThreadedLanes {
        let m = topo.machines();
        let (res_tx, results) = channel();
        let mut cmds = Vec::with_capacity(m);
        let mut threads = Vec::with_capacity(m);
        for node in 0..m {
            let (tx, rx) = channel::<LaneCmd>();
            cmds.push(tx);
            let topo = topo.clone();
            let transport = transport.clone();
            let res_tx = res_tx.clone();
            threads.push(std::thread::spawn(move || {
                let mut h = NodeHandle::new(topo, node, transport, send_threads);
                while let Ok(cmd) = rx.recv() {
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        cmd(&mut h)
                    }));
                    let panicked = out.is_err();
                    if res_tx.send((node, out)).is_err() {
                        return;
                    }
                    if panicked {
                        // The handle's protocol state is unknown after an
                        // unwind; retire the lane (the driver re-raises).
                        return;
                    }
                }
            }));
        }
        ThreadedLanes { cmds, results, threads }
    }

    /// Run one closure per lane concurrently; results in lane order.
    /// Session methods are serialized on `&mut self`, so every received
    /// result belongs to this batch. A lane panic is re-raised here.
    fn run_all<O, F>(&self, fns: Vec<F>) -> Vec<O>
    where
        O: Send + 'static,
        F: FnOnce(&mut NodeHandle<LaneTransport>) -> O + Send + 'static,
    {
        assert_eq!(fns.len(), self.cmds.len(), "one closure per lane");
        for (tx, f) in self.cmds.iter().zip(fns) {
            let cmd: LaneCmd = Box::new(move |h| Box::new(f(h)) as LaneResult);
            tx.send(cmd).expect("lane thread exited early");
        }
        let mut out: Vec<Option<O>> = (0..self.cmds.len()).map(|_| None).collect();
        for _ in 0..self.cmds.len() {
            let (node, r) = self.results.recv().expect("lane thread gone without reporting");
            match r {
                Ok(v) => out[node] = Some(*v.downcast::<O>().expect("lane result type")),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out.into_iter().map(|o| o.expect("one result per lane")).collect()
    }
}

impl Drop for ThreadedLanes {
    fn drop(&mut self) {
        // Disconnect the command channels so every lane thread's recv
        // errors and the thread exits, then reap.
        self.cmds.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// A planned multi-process worker pool plus (when locally spawned) the
/// worker subprocesses backing it.
pub(crate) struct PoolBackend {
    pub(crate) session: crate::cluster::Session,
    pub(crate) procs: Option<crate::cluster::LocalProcs>,
}

impl Drop for PoolBackend {
    fn drop(&mut self) {
        // Release the pool before reaping, so locally-spawned workers
        // exit on SHUTDOWN instead of being killed mid-frame.
        self.session.shutdown();
        if let Some(procs) = &mut self.procs {
            procs.wait_all();
        }
    }
}

enum Backend {
    Lockstep(LocalCluster),
    Threaded(ThreadedLanes),
    Pool(Box<PoolBackend>),
    Remote(Box<RemoteSession>),
}

/// One communicator handle (see module docs for the lifecycle).
pub struct Session {
    mode: ExecMode,
    degrees: Vec<usize>,
    send_threads: usize,
    index_range: i64,
    configured: bool,
    /// Monotonic configure counter: each config epoch owns a disjoint
    /// `epoch << 16` message-tag space on the threaded lanes (same
    /// scoping as pool jobs), so a collective that failed mid-flight on
    /// SOME lanes (e.g. a missized `pre` in
    /// [`ConfigHandle::allreduce_compute`]) cannot leave the lane
    /// sequence numbers desynchronized forever — reconfiguring
    /// resynchronizes every lane onto the fresh epoch.
    epochs: u32,
    out_lens: Vec<usize>,
    in_lens: Vec<usize>,
    backend: Backend,
}

impl Session {
    /// Build an in-process session (lockstep or threaded lanes).
    pub(crate) fn new_in_process(
        mode: ExecMode,
        degrees: Vec<usize>,
        send_threads: usize,
        index_range: i64,
        delay: Option<(CostModel, u64, f64)>,
        node_delays: &[(usize, CostModel)],
    ) -> Result<Session> {
        if index_range < 1 {
            bail!("index range must be >= 1 (got {index_range})");
        }
        let topo = Butterfly::new(degrees.clone(), index_range);
        let m = topo.machines();
        let backend = match mode {
            ExecMode::Lockstep => {
                if delay.is_some() {
                    bail!("cost-model delay injection needs --mode threaded");
                }
                Backend::Lockstep(LocalCluster::new(topo))
            }
            ExecMode::Threaded => {
                let transport = match delay {
                    None => LaneTransport::Mem(MemTransport::new(m)),
                    Some((cost, seed, scale)) => {
                        let mut t = DelayTransport::new(MemTransport::new(m), cost, seed)
                            .with_time_scale(scale);
                        for &(node, cost) in node_delays {
                            t = t.with_node_cost(node, cost);
                        }
                        LaneTransport::Delay(t)
                    }
                };
                Backend::Threaded(ThreadedLanes::spawn(&topo, Arc::new(transport), send_threads))
            }
            ExecMode::MultiProcess => {
                bail!("multi-process sessions are built from a worker pool (CommBuilder)")
            }
        };
        Ok(Session {
            mode,
            degrees,
            send_threads,
            index_range,
            configured: false,
            epochs: 0,
            out_lens: Vec::new(),
            in_lens: Vec::new(),
            backend,
        })
    }

    /// Wrap a planned worker pool as a session (jobs only).
    pub(crate) fn new_pool(degrees: Vec<usize>, send_threads: usize, pool: PoolBackend) -> Session {
        Session {
            mode: ExecMode::MultiProcess,
            degrees,
            send_threads,
            index_range: 0,
            configured: false,
            epochs: 0,
            out_lens: Vec::new(),
            in_lens: Vec::new(),
            backend: Backend::Pool(Box::new(pool)),
        }
    }

    /// Wrap a remote-pool client connection as a session: the raw
    /// two-phase lifecycle against a separately `sar serve`-launched
    /// pool.
    pub(crate) fn new_remote(
        degrees: Vec<usize>,
        send_threads: usize,
        index_range: i64,
        remote: RemoteSession,
    ) -> Session {
        Session {
            mode: ExecMode::MultiProcess,
            degrees,
            send_threads,
            index_range,
            configured: false,
            epochs: 0,
            out_lens: Vec::new(),
            in_lens: Vec::new(),
            backend: Backend::Remote(Box::new(remote)),
        }
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn degrees(&self) -> &[usize] {
        &self.degrees
    }

    /// Logical lanes (= butterfly machine count).
    pub fn lanes(&self) -> usize {
        self.degrees.iter().product()
    }

    pub fn send_threads(&self) -> usize {
        self.send_threads
    }

    /// The allreduce index domain this session was built over (0 for a
    /// worker pool, whose jobs each carry their own domain).
    pub fn index_range(&self) -> i64 {
        self.index_range
    }

    pub(crate) fn pool_mut(&mut self) -> Option<&mut PoolBackend> {
        match &mut self.backend {
            Backend::Pool(p) => Some(p),
            _ => None,
        }
    }

    /// Run the config phase once for a sparsity pattern: `outbound[n]` /
    /// `inbound[n]` are lane `n`'s contributed / requested index sets.
    /// The returned handle borrows the session; reconfiguring (a new
    /// sparsity pattern, e.g. SGD's per-step feature sets) just means
    /// calling `configure` again once the handle is dropped.
    pub fn configure(
        &mut self,
        outbound: Vec<IndexSet>,
        inbound: Vec<IndexSet>,
    ) -> Result<ConfigHandle<'_>> {
        let m = self.lanes();
        if outbound.len() != m || inbound.len() != m {
            bail!(
                "configure needs one outbound and one inbound set per lane \
                 ({m} lanes, got {} outbound / {} inbound)",
                outbound.len(),
                inbound.len()
            );
        }
        self.out_lens = outbound.iter().map(|s| s.len()).collect();
        self.in_lens = inbound.iter().map(|s| s.len()).collect();
        let index_range = self.index_range;
        self.epochs = self.epochs.wrapping_add(1);
        let seq_base = self.epochs.wrapping_shl(16);
        match &mut self.backend {
            Backend::Lockstep(cluster) => {
                cluster.config(outbound, inbound);
            }
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = outbound
                    .into_iter()
                    .zip(inbound)
                    .map(|(o, i)| {
                        move |h: &mut NodeHandle<LaneTransport>| {
                            // Epoch-scoped tags: even if a previous
                            // collective failed on SOME lanes (leaving
                            // their sequence numbers behind their
                            // peers'), this configure resynchronizes
                            // every lane onto a fresh disjoint tag
                            // space — one bad round cannot poison the
                            // session.
                            h.set_seq_base(seq_base);
                            h.config(o, i)
                        }
                    })
                    .collect();
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    r.with_context(|| format!("lane {n} config failed"))?;
                }
            }
            Backend::Remote(remote) => {
                remote.configure(index_range, outbound, inbound)?;
            }
            Backend::Pool(_) => bail!(
                "a locally spawned pool session runs whole jobs (Session::submit / \
                 `sar launch --jobs`); for raw configure/allreduce against a pool, \
                 launch it with `sar serve` and connect with CommBuilder::pool(addr)"
            ),
        }
        self.configured = true;
        Ok(ConfigHandle { sess: self })
    }

    fn check_values<T>(&self, values: &[Vec<T>]) -> Result<()> {
        if !self.configured {
            bail!("allreduce before configure");
        }
        if values.len() != self.lanes() {
            bail!("allreduce needs one value vector per lane ({} lanes, got {})",
                  self.lanes(), values.len());
        }
        check_value_lens(&self.out_lens, values)
    }

    fn allreduce_impl<R: ReduceOp>(&mut self, values: &mut Vec<Vec<R::T>>) -> Result<()> {
        self.check_values(&*values)?;
        let input = std::mem::take(values);
        let reduced = match &mut self.backend {
            Backend::Lockstep(cluster) => cluster.reduce::<R>(input).0,
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = input
                    .into_iter()
                    .map(|v| move |h: &mut NodeHandle<LaneTransport>| h.reduce::<R>(v))
                    .collect();
                let mut out = Vec::with_capacity(self.out_lens.len());
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    out.push(r.with_context(|| format!("lane {n} reduce failed"))?);
                }
                out
            }
            Backend::Remote(remote) => remote.allreduce::<R>(input)?,
            Backend::Pool(_) => bail!("pool sessions run jobs, not raw collectives"),
        };
        *values = reduced;
        Ok(())
    }

    /// One allreduce with the per-lane compute fused in (see
    /// [`ConfigHandle::allreduce_compute`]): `pre(lane, &mut state)`
    /// produces lane values, the collective reduces them,
    /// `post(lane, &mut state, reduced)` absorbs the result. In the
    /// threaded mode both closures run ON the lane threads, so
    /// driver-side compute (e.g. PageRank's SpMV) parallelizes across
    /// lanes instead of serializing on the driver.
    fn allreduce_compute_impl<R, S>(
        &mut self,
        states: Vec<S>,
        pre: Arc<dyn Fn(usize, &mut S) -> Vec<R::T> + Send + Sync>,
        post: Arc<dyn Fn(usize, &mut S, Vec<R::T>) + Send + Sync>,
    ) -> Result<Vec<(S, f64, f64)>>
    where
        R: ReduceOp,
        S: Send + 'static,
    {
        if !self.configured {
            bail!("allreduce before configure");
        }
        if states.len() != self.lanes() {
            bail!(
                "allreduce_compute needs one state per lane ({} lanes, got {})",
                self.lanes(),
                states.len()
            );
        }
        let out_lens = self.out_lens.clone();
        match &mut self.backend {
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = states
                    .into_iter()
                    .enumerate()
                    .map(|(n, mut s)| {
                        let pre = pre.clone();
                        let post = post.clone();
                        let want = out_lens[n];
                        move |h: &mut NodeHandle<LaneTransport>| -> Result<(S, f64, f64), TransportError> {
                            let t0 = Instant::now();
                            let q = pre(n, &mut s);
                            let compute_pre = t0.elapsed();
                            if q.len() != want {
                                // Peers that passed their own check may
                                // already be mid-reduce; the session's
                                // lanes resynchronize on the next
                                // configure (epoch-scoped tags).
                                return Err(TransportError::Io(std::io::Error::new(
                                    std::io::ErrorKind::InvalidData,
                                    format!(
                                        "pre produced {} values but the configured \
                                         outbound set has {want}; reconfigure the \
                                         session before the next collective",
                                        q.len()
                                    ),
                                )));
                            }
                            let t1 = Instant::now();
                            let r = h.reduce::<R>(q)?;
                            let comm = t1.elapsed().as_secs_f64();
                            let t2 = Instant::now();
                            post(n, &mut s, r);
                            Ok((s, (compute_pre + t2.elapsed()).as_secs_f64(), comm))
                        }
                    })
                    .collect();
                let mut out = Vec::with_capacity(self.out_lens.len());
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    out.push(r.with_context(|| format!("lane {n} reduce failed"))?);
                }
                Ok(out)
            }
            Backend::Lockstep(cluster) => driver_compute_round::<R, S, _>(
                states,
                &out_lens,
                &*pre,
                &*post,
                |vals| Ok(cluster.reduce::<R>(vals).0),
            ),
            Backend::Remote(remote) => driver_compute_round::<R, S, _>(
                states,
                &out_lens,
                &*pre,
                &*post,
                |vals| remote.allreduce::<R>(vals),
            ),
            Backend::Pool(_) => bail!("pool sessions run jobs, not raw collectives"),
        }
    }

    fn allreduce_with_bottom_impl<R, F>(
        &mut self,
        values: Vec<Vec<R::T>>,
        bottoms: Vec<F>,
    ) -> Result<Vec<Vec<R::T>>>
    where
        R: ReduceOp,
        F: FnOnce(&IndexSet, &[R::T], &IndexSet) -> Vec<R::T> + Send + 'static,
    {
        self.check_values(&values)?;
        if bottoms.len() != self.lanes() {
            bail!("one bottom transform per lane required");
        }
        match &mut self.backend {
            Backend::Lockstep(cluster) => {
                let cluster: &LocalCluster = cluster;
                let mut slots: Vec<Option<F>> = bottoms.into_iter().map(Some).collect();
                let (got, _trace) = cluster.reduce_with_bottom::<R, _>(values, |node, reduced| {
                    let f = slots[node].take().expect("bottom transform runs once per lane");
                    let p = cluster.node(node);
                    f(p.bottom_down_set(), reduced, p.bottom_up_set())
                });
                Ok(got)
            }
            Backend::Threaded(lanes) => {
                let fns: Vec<_> = values
                    .into_iter()
                    .zip(bottoms)
                    .map(|(v, f)| {
                        move |h: &mut NodeHandle<LaneTransport>| h.reduce_with_bottom::<R, F>(v, f)
                    })
                    .collect();
                let mut out = Vec::with_capacity(self.out_lens.len());
                for (n, r) in lanes.run_all(fns).into_iter().enumerate() {
                    out.push(r.with_context(|| format!("lane {n} reduce failed"))?);
                }
                Ok(out)
            }
            Backend::Remote(remote) => remote.allreduce_with_bottom::<R, F>(values, bottoms),
            Backend::Pool(_) => bail!("pool sessions run jobs, not raw collectives"),
        }
    }
}

/// The driver-side compute-fused round shared by the lockstep and
/// remote backends of [`Session::allreduce_compute_impl`]: run `pre`
/// per lane (timed), size-check, reduce via the backend's closure
/// (timed as comm), run `post` per lane (timed). The threaded backend
/// has its own path because there the closures run ON the lane threads.
fn driver_compute_round<R, S, X>(
    states: Vec<S>,
    out_lens: &[usize],
    pre: &(dyn Fn(usize, &mut S) -> Vec<R::T> + Send + Sync),
    post: &(dyn Fn(usize, &mut S, Vec<R::T>) + Send + Sync),
    reduce: X,
) -> Result<Vec<(S, f64, f64)>>
where
    R: ReduceOp,
    S: Send + 'static,
    X: FnOnce(Vec<Vec<R::T>>) -> Result<Vec<Vec<R::T>>>,
{
    let mut states = states;
    let mut vals = Vec::with_capacity(states.len());
    let mut pre_secs = Vec::with_capacity(states.len());
    for (n, s) in states.iter_mut().enumerate() {
        let t = Instant::now();
        vals.push(pre(n, s));
        pre_secs.push(t.elapsed().as_secs_f64());
    }
    check_value_lens(out_lens, &vals)?;
    let t = Instant::now();
    let reduced = reduce(vals)?;
    let comm = t.elapsed().as_secs_f64();
    let mut out = Vec::with_capacity(states.len());
    for (n, (mut s, r)) in states.into_iter().zip(reduced).enumerate() {
        let t2 = Instant::now();
        post(n, &mut s, r);
        out.push((s, pre_secs[n] + t2.elapsed().as_secs_f64(), comm));
    }
    Ok(out)
}

/// One value vector per configured outbound set, exactly sized — the
/// shared leg of [`Session::check_values`] and the compute-fused paths
/// that produce their values after the handle is already borrowed.
fn check_value_lens<T>(out_lens: &[usize], values: &[Vec<T>]) -> Result<()> {
    for (n, (v, &want)) in values.iter().zip(out_lens).enumerate() {
        if v.len() != want {
            bail!(
                "lane {n}: {} values but the configured outbound set has {want} \
                 indices (reconfigure for a new sparsity pattern)",
                v.len()
            );
        }
    }
    Ok(())
}

/// Proof that the config phase ran; the door to the reduce phase.
pub struct ConfigHandle<'s> {
    sess: &'s mut Session,
}

impl ConfigHandle<'_> {
    pub fn lanes(&self) -> usize {
        self.sess.lanes()
    }

    /// One sparse allreduce: `values[n]` aligned with lane `n`'s
    /// configured outbound set going in, replaced by the reduced values
    /// aligned with its inbound set coming out. Generic over the reduce
    /// operator — `SumF32`, `OrU32` and `MaxF32` all take this one path.
    pub fn allreduce<R: ReduceOp>(&mut self, values: &mut Vec<Vec<R::T>>) -> Result<()> {
        self.sess.allreduce_impl::<R>(values)
    }

    /// Allreduce with a custom bottom-of-butterfly transform per lane:
    /// after the scatter-reduce, `bottoms[n](down_set, reduced, up_set)`
    /// receives lane `n`'s fully-reduced bottom range and must return
    /// one value per `up_set` index to be allgathered. This is the
    /// parameter-server mode of the paper's mini-batch SGD (§III-B):
    /// the bottom owner folds gradients into its persistent model shard
    /// and serves fresh weights back up. (On a remote session the
    /// transform runs client-side between the two wire halves, so the
    /// model state stays in the client process.)
    pub fn allreduce_with_bottom<R, F>(
        &mut self,
        values: Vec<Vec<R::T>>,
        bottoms: Vec<F>,
    ) -> Result<Vec<Vec<R::T>>>
    where
        R: ReduceOp,
        F: FnOnce(&IndexSet, &[R::T], &IndexSet) -> Vec<R::T> + Send + 'static,
    {
        self.sess.allreduce_with_bottom_impl::<R, F>(values, bottoms)
    }

    /// One allreduce with the per-lane compute fused in: for each lane,
    /// `pre(lane, &mut state)` produces the outbound values (exactly
    /// the configured outbound count), the collective reduces them, and
    /// `post(lane, &mut state, reduced)` absorbs the inbound-aligned
    /// result. In threaded sessions both closures run ON the lane
    /// threads, so per-node compute (PageRank's SpMV, a gradient
    /// evaluation) runs in parallel across lanes instead of serially on
    /// the driver (ROADMAP PR 4 follow-up). Returns per-lane
    /// `(state, compute_secs, comm_secs)` in lane order.
    pub fn allreduce_compute<R, S, F, G>(
        &mut self,
        states: Vec<S>,
        pre: F,
        post: G,
    ) -> Result<Vec<(S, f64, f64)>>
    where
        R: ReduceOp,
        S: Send + 'static,
        F: Fn(usize, &mut S) -> Vec<R::T> + Send + Sync + 'static,
        G: Fn(usize, &mut S, Vec<R::T>) + Send + Sync + 'static,
    {
        self.sess.allreduce_compute_impl::<R, S>(states, Arc::new(pre), Arc::new(post))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{MaxF32, OrU32, SumF32};

    fn sets(v: Vec<Vec<i64>>) -> Vec<IndexSet> {
        v.into_iter().map(IndexSet::from_unsorted).collect()
    }

    fn session(mode: ExecMode) -> Session {
        Session::new_in_process(mode, vec![2, 2], 2, 64, None).unwrap()
    }

    fn check_sum_session(mut s: Session) {
        let out = sets(vec![vec![1, 5], vec![5, 9], vec![2], vec![]]);
        let inb = sets(vec![vec![5], vec![1, 2], vec![9], vec![5, 9]]);
        let mut cfg = s.configure(out, inb).unwrap();
        let mut vals = vec![vec![1.0f32, 10.0], vec![20.0, 3.0], vec![7.0], vec![]];
        cfg.allreduce::<SumF32>(&mut vals).unwrap();
        assert_eq!(vals[0], vec![30.0]);
        assert_eq!(vals[1], vec![1.0, 7.0]);
        assert_eq!(vals[2], vec![3.0]);
        assert_eq!(vals[3], vec![30.0, 3.0]);
        // same config, second reduce (values doubled)
        let mut vals = vec![vec![2.0f32, 20.0], vec![40.0, 6.0], vec![14.0], vec![]];
        cfg.allreduce::<SumF32>(&mut vals).unwrap();
        assert_eq!(vals[0], vec![60.0]);
    }

    #[test]
    fn lockstep_session_reduces_and_reuses_config() {
        check_sum_session(session(ExecMode::Lockstep));
    }

    #[test]
    fn threaded_session_reduces_and_reuses_config() {
        check_sum_session(session(ExecMode::Threaded));
    }

    #[test]
    fn or_and_max_flow_through_the_same_path() {
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![3], vec![3], vec![7], vec![]]);
            let inb = sets(vec![vec![3, 7], vec![3], vec![3], vec![7]]);
            let mut cfg = s.configure(out.clone(), inb.clone()).unwrap();
            let mut vals = vec![vec![0b01u32], vec![0b10], vec![0b100], vec![]];
            cfg.allreduce::<OrU32>(&mut vals).unwrap();
            assert_eq!(vals[0], vec![0b11, 0b100], "{mode:?}");
            assert_eq!(vals[3], vec![0b100], "{mode:?}");
            drop(cfg);
            let mut cfg = s.configure(out, inb).unwrap();
            let mut vals = vec![vec![2.0f32], vec![5.0], vec![-1.0], vec![]];
            cfg.allreduce::<MaxF32>(&mut vals).unwrap();
            assert_eq!(vals[0], vec![5.0, -1.0], "{mode:?}");
            assert_eq!(vals[1], vec![5.0], "{mode:?}");
        }
    }

    #[test]
    fn misuse_is_a_readable_error() {
        let mut s = session(ExecMode::Lockstep);
        // allreduce before configure
        let mut vals: Vec<Vec<f32>> = vec![vec![]; 4];
        assert!(s.allreduce_impl::<SumF32>(&mut vals).is_err());
        // wrong lane count
        assert!(s.configure(sets(vec![vec![]]), sets(vec![vec![]])).is_err());
        // wrong value length vs configured outbound
        let out = sets(vec![vec![1], vec![], vec![], vec![]]);
        let inb = sets(vec![vec![1], vec![], vec![], vec![]]);
        let mut cfg = s.configure(out, inb).unwrap();
        let mut vals = vec![vec![1.0f32, 2.0], vec![], vec![], vec![]];
        let err = cfg.allreduce::<SumF32>(&mut vals).unwrap_err();
        assert!(format!("{err:#}").contains("outbound set"), "got {err:#}");
    }

    /// Satellite (ROADMAP PR 4 follow-up): the compute-fused allreduce
    /// produces the same reduction as the plain path in both in-process
    /// modes — in threaded sessions the `pre`/`post` closures run on
    /// the lane threads, i.e. the driver's per-node compute
    /// parallelizes.
    #[test]
    fn allreduce_compute_matches_plain_path() {
        struct LaneState {
            scale: f32,
            got: Vec<f32>,
        }
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![1, 5], vec![5, 9], vec![2], vec![]]);
            let inb = sets(vec![vec![5], vec![1, 2], vec![9], vec![5, 9]]);
            let mut cfg = s.configure(out, inb).unwrap();
            let states: Vec<LaneState> =
                (0..4).map(|_| LaneState { scale: 1.0, got: Vec::new() }).collect();
            let base: Vec<Vec<f32>> =
                vec![vec![1.0, 10.0], vec![20.0, 3.0], vec![7.0], vec![]];
            let got = cfg
                .allreduce_compute::<SumF32, LaneState, _, _>(
                    states,
                    move |n, st| base[n].iter().map(|v| v * st.scale).collect(),
                    |_, st, reduced| st.got = reduced,
                )
                .unwrap();
            assert_eq!(got[0].0.got, vec![30.0], "{mode:?}");
            assert_eq!(got[1].0.got, vec![1.0, 7.0], "{mode:?}");
            assert_eq!(got[2].0.got, vec![3.0], "{mode:?}");
            assert_eq!(got[3].0.got, vec![30.0, 3.0], "{mode:?}");
            for (_, compute, comm) in &got {
                assert!(*compute >= 0.0 && *comm >= 0.0, "{mode:?}");
            }
        }
    }

    /// A `pre` that produces the wrong value count is a readable error
    /// in both modes, not a protocol panic or a hang.
    #[test]
    fn allreduce_compute_missized_pre_is_an_error() {
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![1], vec![], vec![], vec![]]);
            let inb = sets(vec![vec![1], vec![], vec![], vec![]]);
            let mut cfg = s.configure(out, inb).unwrap();
            let err = cfg
                .allreduce_compute::<SumF32, (), _, _>(
                    vec![(); 4],
                    |_, _| vec![0.0; 3],
                    |_, _, _| {},
                )
                .unwrap_err();
            assert!(format!("{err:#}").contains("outbound set"), "{mode:?}: got {err:#}");
        }
    }

    #[test]
    fn bottom_transform_runs_per_lane() {
        for mode in [ExecMode::Lockstep, ExecMode::Threaded] {
            let mut s = session(mode);
            let out = sets(vec![vec![1], vec![1], vec![], vec![]]);
            let inb = sets(vec![vec![1], vec![1], vec![1], vec![]]);
            let mut cfg = s.configure(out, inb).unwrap();
            // bottom transform: negate the reduced sums before gathering
            let bottoms: Vec<_> = (0..4)
                .map(|_| {
                    |down: &IndexSet, reduced: &[f32], up: &IndexSet| {
                        assert_eq!(down.len(), reduced.len());
                        up.as_slice()
                            .iter()
                            .map(|i| {
                                down.position(*i)
                                    .map(|p| -reduced[p])
                                    .unwrap_or(0.0)
                            })
                            .collect::<Vec<f32>>()
                    }
                })
                .collect();
            let got = cfg
                .allreduce_with_bottom::<SumF32, _>(
                    vec![vec![2.0], vec![3.0], vec![], vec![]],
                    bottoms,
                )
                .unwrap();
            assert_eq!(got[0], vec![-5.0], "{mode:?}");
            assert_eq!(got[1], vec![-5.0], "{mode:?}");
            assert_eq!(got[2], vec![-5.0], "{mode:?}");
            assert_eq!(got[3], Vec::<f32>::new(), "{mode:?}");
        }
    }
}
