//! Session-based communicator API: one `Comm` handle, any app, any mode.
//!
//! The paper's Sparse Allreduce is a *primitive* — §VI applies it to
//! PageRank, and the abstract names spectral partitioning, regression,
//! topic models and clustering as equally natural clients. This module
//! turns the repo's PageRank-shaped entry points into an MPI-style
//! communicator session:
//!
//! ```text
//!   CommBuilder ──build(range)──► Session ──configure(out, in)──► ConfigHandle
//!        │                          ▲                                │
//!        │                          └──── allreduce::<R>(&mut v) ────┘  (repeatedly)
//!        └──────── submit(&JobSpec) ─────► JobOutcome   (whole-app door)
//! ```
//!
//! * [`CommBuilder`] fixes the communicator's shape: butterfly degree
//!   schedule, execution mode ([`ExecMode`]), replication, sender
//!   threads.
//! * [`Session::configure`] runs the paper's config phase once per
//!   sparsity pattern; the returned [`ConfigHandle`] exposes
//!   [`ConfigHandle::allreduce`], generic over [`crate::sparse::ReduceOp`],
//!   so `SumF32` (PageRank, SGD), `OrU32` (HyperANF/HADI diameter
//!   sketches) and `MaxF32` all flow through one code path.
//! * [`Session::submit`] / [`CommBuilder::submit`] run a whole
//!   application job ([`JobSpec`]) under the session's mode — the same
//!   job descriptor the `cluster` plane ships to a long-lived worker
//!   pool, so `sar launch` can run pagerank *then* diameter against one
//!   JOINed pool without restarting a worker.
//!
//! The in-process backends (lockstep, threaded) expose the raw
//! two-phase lifecycle directly. Multi-process sessions come in two
//! shapes: a locally spawned pool runs whole job descriptors (the
//! workers run the identical per-node loops from `apps::`), while a
//! [`CommBuilder::pool`] session connects to a separately
//! `sar serve`-launched pool and exposes the raw lifecycle *remotely* —
//! the client streams its sparsity pattern and per-round sparse values,
//! the pool's app-agnostic generic engine reduces them
//! ([`remote::RemoteSession`]), so any client workload runs distributed
//! without the pool knowing its name.

pub mod builder;
pub mod job;
pub mod remote;
pub mod run;
pub mod session;

pub use builder::CommBuilder;
pub use job::{parse_job_names, AppKind, JobOutcome, JobSpec};
pub use remote::RemoteSession;
pub use session::{ConfigHandle, Session};

use anyhow::{bail, Result};

/// How a communicator session executes its collectives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Sequential lockstep in one thread (`LocalCluster`): the
    /// deterministic oracle.
    Lockstep,
    /// One worker thread per node over a shared in-process transport.
    Threaded,
    /// One worker OS process per node over TCP (`cluster::` plane).
    MultiProcess,
}

impl ExecMode {
    /// Every accepted spelling, kept in one place so the parse error and
    /// the docs can't drift apart.
    pub const SPELLINGS: &'static str =
        "lockstep|local, threaded|threads, distributed|multiprocess|mp|cluster";

    pub fn parse(s: &str) -> Result<ExecMode> {
        match s {
            "lockstep" | "local" => Ok(ExecMode::Lockstep),
            "threaded" | "threads" => Ok(ExecMode::Threaded),
            "distributed" | "multiprocess" | "mp" | "cluster" => Ok(ExecMode::MultiProcess),
            other => bail!(
                "unknown exec mode `{other}` (accepted: {})",
                ExecMode::SPELLINGS
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_accepts_every_documented_spelling() {
        for (s, want) in [
            ("lockstep", ExecMode::Lockstep),
            ("local", ExecMode::Lockstep),
            ("threaded", ExecMode::Threaded),
            ("threads", ExecMode::Threaded),
            ("distributed", ExecMode::MultiProcess),
            ("multiprocess", ExecMode::MultiProcess),
            ("mp", ExecMode::MultiProcess),
            ("cluster", ExecMode::MultiProcess),
        ] {
            assert_eq!(ExecMode::parse(s).unwrap(), want, "spelling `{s}`");
        }
    }

    #[test]
    fn exec_mode_error_lists_all_spellings() {
        let err = ExecMode::parse("quantum").unwrap_err();
        let msg = format!("{err}");
        for spelling in ["lockstep", "local", "threaded", "threads", "distributed",
                         "multiprocess", "mp", "cluster"] {
            assert!(msg.contains(spelling), "error must list `{spelling}`: {msg}");
        }
    }
}
