//! Job descriptors: what one application run over a communicator looks
//! like, independent of execution mode.
//!
//! A [`JobSpec`] names an application ([`AppKind`]), its dataset/shard
//! reference and its iteration plan. The in-process backends drive the
//! job through [`crate::comm::Session`]'s configure/allreduce lifecycle;
//! the multi-process backend ships the same descriptor to a worker pool
//! over the `cluster` control plane (`CtrlMsg::Job`), where each worker
//! runs the identical per-node loop from `apps::`.

use crate::obs::RunMetrics;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Which application a job runs (and therefore which reduce operator
/// its collective uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    /// PageRank over `SumF32` (paper §I-A2, §VI-E).
    Pagerank,
    /// HADI effective-diameter sketches over `OrU32` (paper eq. 3).
    Diameter,
    /// Mini-batch SGD over `SumF32` with the parameter-server bottom
    /// (paper §III-B).
    Sgd,
}

impl AppKind {
    pub fn key(&self) -> &'static str {
        match self {
            AppKind::Pagerank => "pagerank",
            AppKind::Diameter => "diameter",
            AppKind::Sgd => "sgd",
        }
    }

    pub fn parse(s: &str) -> Result<AppKind> {
        match s {
            "pagerank" => Ok(AppKind::Pagerank),
            "diameter" => Ok(AppKind::Diameter),
            "sgd" => Ok(AppKind::Sgd),
            other => bail!("unknown app `{other}` (pagerank|diameter|sgd)"),
        }
    }
}

/// Zipf exponent of the synthetic SGD feature distribution. Fixed (not a
/// [`JobSpec`] field) so every execution mode samples the identical
/// power-law without another knob to keep in sync across the wire.
pub const SGD_ZIPF_ALPHA: f64 = 1.1;

/// Parse a comma-separated job list (`"pagerank,diameter"`) into
/// validated app names — the ONE implementation behind both the
/// `sar launch --jobs` flag and the `[run] jobs` config key, so the two
/// spellings can't drift in what they accept.
pub fn parse_job_names(list: &str) -> Result<Vec<String>> {
    let names: Vec<String> = list
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        bail!("job list must name at least one app (pagerank|diameter|sgd)");
    }
    for name in &names {
        AppKind::parse(name).with_context(|| format!("job list entry `{name}`"))?;
    }
    Ok(names)
}

/// Iteration ceiling per job. A worker pool scopes each job's message
/// tags to `job_id << 16`, i.e. 2^16 collectives per job; SGD spends
/// two collectives per step (dynamic config + reduce), so bounding
/// iterations at 30 000 keeps every app comfortably inside its tag
/// budget — without this, a long job's tags would silently alias the
/// next job's.
pub const MAX_JOB_ITERS: usize = 30_000;

/// One application run over a communicator, in any execution mode.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Report prefix (multi-job launches attribute output lines by it).
    pub name: String,
    pub app: AppKind,
    /// Synthetic dataset preset key (pagerank, diameter).
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    /// Iteration plan: PageRank iterations, diameter hops, SGD steps.
    pub iters: usize,
    /// `sar shard` directory (pagerank only): load per-node CSRs from
    /// disk instead of regenerating the dataset.
    pub shards: Option<PathBuf>,
    /// Diameter: Flajolet–Martin sketches per vertex.
    pub sketches: usize,
    /// SGD: classes, examples per worker per step, learning rate, raw
    /// feature-space size, active features per example.
    pub classes: usize,
    pub batch: usize,
    pub lr: f32,
    pub features: i64,
    pub feats_per_ex: usize,
}

impl JobSpec {
    /// A PageRank job (the historical default workload).
    pub fn pagerank() -> JobSpec {
        JobSpec {
            name: "pagerank".to_string(),
            app: AppKind::Pagerank,
            dataset: "twitter".to_string(),
            scale: 0.05,
            seed: 42,
            iters: 10,
            shards: None,
            sketches: 0,
            classes: 0,
            batch: 0,
            lr: 0.0,
            features: 0,
            feats_per_ex: 0,
        }
    }

    /// A HADI diameter job: `iters` is the (fixed) hop count. The
    /// OR-reduce is idempotent and the sketches monotone, so running
    /// past saturation cannot change the result — a fixed hop count is
    /// what makes the checksum comparable across execution modes.
    pub fn diameter() -> JobSpec {
        JobSpec {
            name: "diameter".to_string(),
            app: AppKind::Diameter,
            iters: 8,
            sketches: 8,
            seed: 7,
            ..JobSpec::pagerank()
        }
    }

    /// A mini-batch SGD job over the synthetic power-law classification
    /// data (`NativeGradEngine` in every mode, so results are comparable).
    pub fn sgd() -> JobSpec {
        JobSpec {
            name: "sgd".to_string(),
            app: AppKind::Sgd,
            iters: 10,
            classes: 4,
            batch: 16,
            lr: 0.5,
            features: 500,
            feats_per_ex: 6,
            seed: 123,
            ..JobSpec::pagerank()
        }
    }

    /// Sanity checks shared by every backend, so a bad spec fails at
    /// submit time with a readable error rather than deep in a loop.
    pub fn validate(&self) -> Result<()> {
        if self.iters == 0 {
            bail!("job `{}`: iters must be >= 1", self.name);
        }
        if self.iters > MAX_JOB_ITERS {
            bail!(
                "job `{}`: {} iterations exceeds the per-job collective budget \
                 ({MAX_JOB_ITERS}; each pool job owns 2^16 message tags)",
                self.name,
                self.iters
            );
        }
        match self.app {
            AppKind::Pagerank => {}
            AppKind::Diameter => {
                if self.sketches == 0 {
                    bail!("job `{}`: diameter needs sketches >= 1", self.name);
                }
                if self.shards.is_some() {
                    bail!(
                        "job `{}`: --shards is a pagerank-shaped ingest (per-node CSR \
                         weights); diameter regenerates its dataset",
                        self.name
                    );
                }
            }
            AppKind::Sgd => {
                if self.classes == 0 || self.batch == 0 || self.features <= 0
                    || self.feats_per_ex == 0
                {
                    bail!(
                        "job `{}`: sgd needs classes/batch/features/feats-per-ex >= 1",
                        self.name
                    );
                }
                if self.shards.is_some() {
                    bail!("job `{}`: sgd samples synthetic data; --shards does not apply",
                          self.name);
                }
            }
        }
        Ok(())
    }
}

/// Outcome of one job, comparable across execution modes via `checksum`.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub job: String,
    pub app: AppKind,
    /// The cross-mode determinism probe: Σ over logical nodes of the
    /// app's per-node probe (PageRank `p[0]`, diameter's first sketch,
    /// SGD's final per-worker loss).
    pub checksum: f64,
    pub wall_secs: f64,
    pub config_secs: f64,
    /// Per logical node (in-process) or per reporting worker (pool).
    pub per_node: Vec<RunMetrics>,
    /// SGD only (in-process): mean loss per step.
    pub losses: Vec<f32>,
    /// Diameter only (in-process): estimated neighbourhood function N(h).
    pub neighbourhood: Vec<f64>,
    /// Workers that died during a pool run (masked by replication).
    pub dead: Vec<usize>,
}

impl JobOutcome {
    /// Aggregate comm fraction across nodes (same definition as
    /// `coordinator::PageRankRun::comm_fraction`).
    pub fn comm_fraction(&self) -> f64 {
        let comm: f64 = self.per_node.iter().map(|m| m.total_comm()).sum();
        let total: f64 = self.per_node.iter().map(|m| m.total()).sum();
        if total == 0.0 {
            0.0
        } else {
            comm / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn app_kind_round_trips_through_keys() {
        for app in [AppKind::Pagerank, AppKind::Diameter, AppKind::Sgd] {
            assert_eq!(AppKind::parse(app.key()).unwrap(), app);
        }
        assert!(AppKind::parse("kmeans").is_err());
    }

    #[test]
    fn default_specs_validate() {
        JobSpec::pagerank().validate().unwrap();
        JobSpec::diameter().validate().unwrap();
        JobSpec::sgd().validate().unwrap();
    }

    #[test]
    fn job_name_lists_parse_and_reject() {
        assert_eq!(
            parse_job_names("pagerank, diameter,sgd").unwrap(),
            vec!["pagerank", "diameter", "sgd"]
        );
        assert!(parse_job_names(",").is_err());
        let err = parse_job_names("pagerank,kmeans").unwrap_err();
        assert!(format!("{err:#}").contains("kmeans"), "got: {err:#}");
    }

    #[test]
    fn bad_specs_fail_readably() {
        let z = JobSpec { iters: 0, ..JobSpec::pagerank() };
        assert!(z.validate().is_err());
        let big = JobSpec { iters: MAX_JOB_ITERS + 1, ..JobSpec::pagerank() };
        let err = big.validate().unwrap_err();
        assert!(format!("{err:#}").contains("budget"), "got: {err:#}");
        let d = JobSpec { sketches: 0, ..JobSpec::diameter() };
        assert!(d.validate().is_err());
        let d = JobSpec { shards: Some("x".into()), ..JobSpec::diameter() };
        assert!(format!("{:#}", d.validate().unwrap_err()).contains("diameter"));
        let s = JobSpec { classes: 0, ..JobSpec::sgd() };
        assert!(s.validate().is_err());
    }
}
