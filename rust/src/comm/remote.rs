//! Client side of the remote collective plane: drive a `sar serve`d
//! worker pool through the paper's raw two-phase lifecycle from a
//! separate process.
//!
//! [`RemoteSession::connect`] dials the pool's client port
//! (connect-retry, so a client started before the pool wins the race),
//! reads the pool-shape handshake, and then speaks the
//! CONFIGURE/VALUES/RESULT cycle of [`crate::cluster::serve`]:
//!
//! * [`RemoteSession::configure`] streams one CONFIGURE per lane — the
//!   per-worker *index scatter* of `configure(out, in)`; the pool's
//!   CONFIG_DONE barrier answers with the collective's pool job id.
//! * [`RemoteSession::allreduce`] streams one VALUES per lane and
//!   gathers one RESULT per lane — generic over [`ReduceOp`] through
//!   [`crate::cluster::proto::reduce_op_code`], so `SumF32`, `OrU32`
//!   and `MaxF32` all flow through one path.
//! * [`RemoteSession::allreduce_with_bottom`] splits the collective on
//!   the wire: workers run the scatter-reduce half and return each
//!   lane's fully-reduced bottom range with its down/up index sets;
//!   the client applies the bottom transform (the §III-B
//!   parameter-server fold, holding its model state client-side) and
//!   streams the transformed values into the allgather half.
//!
//! Only index sets and sparse values ever cross the ingress — the
//! client never ships a dense vector, keeping the client→pool link as
//! sparse as the data-plane links inside the pool.
//!
//! The serve plane is multi-tenant (see [`crate::cluster::mux`]): many
//! `RemoteSession`s share one pool concurrently, each with its own
//! job-scoped worker state. A session past the pool's live limit waits
//! in the pool's admission queue — visible here as a slow handshake —
//! and an idle session can be evicted by the pool's keepalive, which
//! surfaces as a FAILED answer on the next call. Dropping the session
//! sends a polite goodbye so the pool frees its state immediately.

use crate::cluster::proto::{
    recv_ctrl, reduce_op_code, send_ctrl, ConfigureMsg, CtrlMsg, ResultMsg, ValuesMsg, CLIENT,
    RES_STAGE_BOTTOM, RES_STAGE_FINAL, VAL_STAGE_DOWN, VAL_STAGE_FULL, VAL_STAGE_UP,
};
use crate::obs::trace::{self, TraceTags};
use crate::obs::{self, Span};
use crate::sparse::{IndexSet, ReduceOp};
use crate::transport::{connect_with_retry, wire, RetryPolicy};
use anyhow::{bail, Context, Result};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a single client read may block. An expiry is NOT fatal:
/// the pool may legitimately be slow — a straggling replica, a deep
/// admission queue, another tenant's long round — so expiries are
/// retried up to [`READ_RETRIES`] times per message before the
/// session gives up with a readable error.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Read-timeout expiries tolerated per message. Total patience
/// (`READ_RETRIES × READ_TIMEOUT`) matches the coordinator's default
/// 120 s phase deadline, so a pool that is going to answer at all
/// answers within it.
const READ_RETRIES: u32 = 4;

/// A live connection to a `sar serve` pool's client port (see module
/// docs). Obtained via `CommBuilder::pool(addr)` + `build(range)`,
/// which wraps it in an ordinary [`super::Session`].
pub struct RemoteSession {
    rd: TcpStream,
    wr: Mutex<TcpStream>,
    degrees: Vec<usize>,
    send_threads: usize,
    /// Client-side config counter (the pool maps it to a pool-unique
    /// job id in the CONFIG_DONE ack).
    cfg_seq: u32,
    /// Pool job id of the live config.
    job: Option<u32>,
    /// Collective round counter within the live config.
    seq: u32,
    /// Recycled VALUES-payload encode buffer: in steady state (same
    /// pattern round after round) no per-round wire allocation happens
    /// on the client either — the counterpart of the generic engine's
    /// worker-side scratch.
    wire_buf: Vec<u8>,
    /// The pool's last advisory health census (one grade per physical
    /// worker; empty until the first census arrives).
    pool_health: Vec<u32>,
    /// Pre-resolved obs handles: client-observed round RTT (send of the
    /// first VALUES to the last RESULT) and read-timeout retries.
    rtt_hist: Arc<obs::Histogram>,
    retries: Arc<obs::Counter>,
}

impl Drop for RemoteSession {
    fn drop(&mut self) {
        // Polite goodbye: the multi-tenant serve plane ends the session
        // (freeing its admission slot and its workers' scatter state)
        // on receipt, instead of waiting for the connection teardown to
        // surface. Best-effort — the socket may already be gone.
        let _ = send_ctrl(&self.wr, CLIENT, &CtrlMsg::Shutdown);
    }
}

impl RemoteSession {
    /// Dial a pool's client port and read the pool-shape handshake.
    pub fn connect(addr: &str, send_threads: usize) -> Result<RemoteSession> {
        let sock: SocketAddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving pool address `{addr}`"))?
            .next()
            .with_context(|| format!("pool address `{addr}` resolved to no address"))?;
        let stream = connect_with_retry(&sock, &RetryPolicy::default())
            .with_context(|| format!("connecting to the pool's client port {sock}"))?;
        stream.set_nodelay(true)?;
        let mut rd = stream.try_clone().context("cloning the pool stream")?;
        rd.set_read_timeout(Some(READ_TIMEOUT))?;
        // The handshake is where a queued admission waits: keep the
        // same patience as any other read (the pool answers the
        // moment a live slot frees up).
        let mut expiries = 0u32;
        let msg = loop {
            match recv_ctrl(&mut rd) {
                Ok((_, m)) => break m,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    expiries += 1;
                    if expiries >= READ_RETRIES {
                        bail!(
                            "no handshake from the pool at {addr} in {:?} — full \
                             admission queue, or not a `sar serve` client port?",
                            READ_TIMEOUT * expiries
                        );
                    }
                    log::info!("pool handshake pending (admission queue?); waiting");
                }
                Err(e) => {
                    return Err(anyhow::Error::from(e)
                        .context("reading the pool-shape handshake"));
                }
            }
        };
        let plan = match msg {
            CtrlMsg::Plan(p) => p,
            other => bail!(
                "the pool sent {other:?} instead of the shape handshake — is {addr} \
                 a `sar serve` client port?"
            ),
        };
        let degrees: Vec<usize> = plan.degrees.iter().map(|&k| k as usize).collect();
        if plan.replication > 1 {
            log::info!(
                "pool at {addr} replicates ×{}: worker deaths are masked while every \
                 lane keeps a live replica (paper §V)",
                plan.replication
            );
        }
        log::info!(
            "connected to pool at {addr}: {} workers, schedule {degrees:?}",
            plan.world
        );
        Ok(RemoteSession {
            rd,
            wr: Mutex::new(stream),
            degrees,
            send_threads: send_threads.max(1),
            cfg_seq: 0,
            job: None,
            seq: 0,
            wire_buf: Vec::new(),
            pool_health: Vec::new(),
            rtt_hist: obs::global().histogram("client.round_rtt"),
            retries: obs::global().counter("client.retries"),
        })
    }

    /// The pool's butterfly degree schedule (clients must match it).
    pub fn degrees(&self) -> &[usize] {
        &self.degrees
    }

    /// Logical lanes (= pool workers ÷ replication): the batch width
    /// this session speaks in.
    pub fn lanes(&self) -> usize {
        self.degrees.iter().product()
    }

    /// The pool's last advisory health census: one grade per physical
    /// worker (`HEALTH_NORMAL` | `HEALTH_SUSPECT` | `HEALTH_UNHEALTHY`
    /// in [`crate::cluster::proto`]), empty until the pool's first
    /// census arrives (it rides behind each config ack).
    pub fn pool_health(&self) -> &[u32] {
        &self.pool_health
    }

    /// Read the next pool message. A FAILED answer becomes a readable
    /// error carrying the pool's cause; an advisory health census is
    /// absorbed; a read timeout is retried — the pool may just be slow
    /// (a straggling replica, another tenant's long round) — and only
    /// repeated expiry becomes an error.
    fn recv(&mut self) -> Result<CtrlMsg> {
        let mut expiries = 0u32;
        loop {
            match recv_ctrl(&mut self.rd) {
                Ok((_, CtrlMsg::Failed { error })) => bail!("pool reported failure: {error}"),
                Ok((_, CtrlMsg::PoolHealth { grades })) => self.pool_health = grades,
                Ok((_, msg)) => return Ok(msg),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    expiries += 1;
                    self.retries.inc();
                    if expiries >= READ_RETRIES {
                        bail!(
                            "pool is straggling: no answer in {:?} ({expiries} read \
                             timeouts) — still connected, but stuck or overloaded",
                            READ_TIMEOUT * expiries
                        );
                    }
                    log::warn!(
                        "pool read timed out (attempt {expiries}/{READ_RETRIES}); retrying"
                    );
                }
                Err(e) => {
                    return Err(anyhow::Error::from(e).context("reading from the pool"));
                }
            }
        }
    }

    /// Stream a sparsity pattern to the pool (one CONFIGURE per lane)
    /// and wait for the pool-wide config barrier. Call again for a new
    /// pattern (e.g. SGD's per-step feature sets) — the pool rebuilds
    /// its protocol handles over the same long-lived fabric.
    pub fn configure(
        &mut self,
        index_range: i64,
        outbound: Vec<IndexSet>,
        inbound: Vec<IndexSet>,
    ) -> Result<()> {
        let m = self.lanes();
        if outbound.len() != m || inbound.len() != m {
            bail!(
                "configure needs one index set per lane ({m} lanes, got {} outbound / \
                 {} inbound)",
                outbound.len(),
                inbound.len()
            );
        }
        self.cfg_seq += 1;
        self.job = None;
        self.seq = 0;
        for (lane, (o, i)) in outbound.into_iter().zip(inbound).enumerate() {
            let msg = CtrlMsg::Configure(ConfigureMsg {
                job: self.cfg_seq,
                lane: lane as u32,
                index_range,
                send_threads: self.send_threads as u32,
                outbound: o.into_vec(),
                inbound: i.into_vec(),
            });
            send_ctrl(&self.wr, CLIENT, &msg)
                .with_context(|| format!("streaming lane {lane}'s sparsity pattern"))?;
        }
        match self.recv().context("waiting for the pool's config barrier")? {
            CtrlMsg::ConfigDone { job } => {
                self.job = Some(job);
                Ok(())
            }
            other => bail!("expected the config ack, got {other:?}"),
        }
    }

    /// One remote sparse allreduce: `values[n]` aligned with lane `n`'s
    /// configured outbound set; the reduced values aligned with its
    /// inbound set come back.
    pub fn allreduce<R: ReduceOp>(&mut self, values: Vec<Vec<R::T>>) -> Result<Vec<Vec<R::T>>> {
        self.seq += 1;
        let span = Span::start(&self.rtt_hist);
        let tspan = trace::ring().span("client.round", self.ttags());
        self.send_round::<R>(VAL_STAGE_FULL, values)?;
        let results = self.collect_round(RES_STAGE_FINAL)?;
        tspan.finish();
        span.finish();
        decode_lane_values::<R>(results)
    }

    /// Remote allreduce with a client-side bottom transform per lane
    /// (the §III-B parameter-server mode): after the pool's
    /// scatter-reduce half, `bottoms[n](down_set, reduced, up_set)`
    /// receives lane `n`'s fully-reduced bottom range and must return
    /// one value per `up_set` index for the allgather half — the same
    /// contract as [`crate::allreduce::LocalCluster::reduce_with_bottom`],
    /// with the transform (and any model state it closes over) living
    /// in the client process.
    pub fn allreduce_with_bottom<R, F>(
        &mut self,
        values: Vec<Vec<R::T>>,
        bottoms: Vec<F>,
    ) -> Result<Vec<Vec<R::T>>>
    where
        R: ReduceOp,
        F: FnOnce(&IndexSet, &[R::T], &IndexSet) -> Vec<R::T>,
    {
        if bottoms.len() != self.lanes() {
            bail!("one bottom transform per lane required");
        }
        self.seq += 1;
        let span = Span::start(&self.rtt_hist);
        let tspan = trace::ring().span("client.round", self.ttags());
        self.send_round::<R>(VAL_STAGE_DOWN, values)?;
        let mids = self.collect_round(RES_STAGE_BOTTOM)?;
        let mut ups: Vec<Vec<R::T>> = Vec::with_capacity(mids.len());
        for (lane, (r, f)) in mids.into_iter().zip(bottoms).enumerate() {
            let reduced = wire::decode_values::<R>(&r.payload)
                .with_context(|| format!("decoding lane {lane}'s bottom values"))?;
            if reduced.len() != r.down_idx.len() {
                bail!(
                    "lane {lane}: {} bottom values but {} bottom indices",
                    reduced.len(),
                    r.down_idx.len()
                );
            }
            let down = IndexSet::from_sorted(r.down_idx);
            let up = IndexSet::from_sorted(r.up_idx);
            let out = f(&down, &reduced, &up);
            if out.len() != up.len() {
                bail!(
                    "lane {lane}: the bottom transform must return one value per up-set \
                     index ({} != {})",
                    out.len(),
                    up.len()
                );
            }
            ups.push(out);
        }
        self.send_round::<R>(VAL_STAGE_UP, ups)?;
        let results = self.collect_round(RES_STAGE_FINAL)?;
        tspan.finish();
        span.finish();
        decode_lane_values::<R>(results)
    }

    /// Trace tags for the current round, in the client process's ring:
    /// the POOL job id (so client spans line up with the pool's own
    /// `worker.round` spans when both traces are inspected), this
    /// session's round counter, and the serve-relay pseudo-node.
    fn ttags(&self) -> TraceTags {
        TraceTags {
            job: self.job.unwrap_or(0),
            round: self.seq,
            node: trace::SERVE_NODE,
            ..Default::default()
        }
    }

    /// Stream one VALUES per lane for the current round.
    fn send_round<R: ReduceOp>(&mut self, stage: u8, values: Vec<Vec<R::T>>) -> Result<()> {
        let job = self.job.context("allreduce before configure")?;
        let op = reduce_op_code::<R>().context(
            "this reduce operator has no remote wire encoding (SumF32 | OrU32 | MaxF32)",
        )?;
        for (lane, v) in values.into_iter().enumerate() {
            // Encode into the recycled buffer and reclaim it after the
            // frame is flushed — zero steady-state wire allocations.
            let mut payload = std::mem::take(&mut self.wire_buf);
            wire::encode_values_into::<R>(&v, &mut payload);
            let msg = CtrlMsg::Values(ValuesMsg {
                job,
                seq: self.seq,
                lane: lane as u32,
                op,
                stage,
                payload,
            });
            send_ctrl(&self.wr, CLIENT, &msg)
                .with_context(|| format!("sending lane {lane}'s values"))?;
            if let CtrlMsg::Values(m) = msg {
                self.wire_buf = m.payload;
            }
        }
        Ok(())
    }

    /// Gather one RESULT per lane for the current round (lanes answer
    /// in any order; a stale round's result is dropped with a warning).
    fn collect_round(&mut self, stage: u8) -> Result<Vec<ResultMsg>> {
        let job = self.job.expect("round in flight");
        let seq = self.seq;
        let m = self.lanes();
        let mut got: Vec<Option<ResultMsg>> = (0..m).map(|_| None).collect();
        let mut have = 0usize;
        while have < m {
            match self.recv().context("waiting for reduced values")? {
                CtrlMsg::Result(r) => slot_result(&mut got, &mut have, r, job, seq, stage)?,
                other => bail!("expected RESULT, got {other:?}"),
            }
        }
        Ok(got.into_iter().map(|r| r.expect("one result per lane")).collect())
    }
}

/// File a RESULT into its lane slot; results from other rounds are
/// dropped with a warning (they can only be stale).
fn slot_result(
    got: &mut [Option<ResultMsg>],
    have: &mut usize,
    r: ResultMsg,
    job: u32,
    seq: u32,
    stage: u8,
) -> Result<()> {
    if r.job != job || r.seq != seq || r.stage != stage {
        log::warn!(
            "dropping stale RESULT (collective {} round {} stage {})",
            r.job,
            r.seq,
            r.stage
        );
        return Ok(());
    }
    let lane = r.lane as usize;
    if lane >= got.len() {
        bail!("RESULT names lane {lane} but the session has {} lanes", got.len());
    }
    if got[lane].replace(r).is_none() {
        *have += 1;
    }
    Ok(())
}

/// Decode each lane's RESULT payload into values.
fn decode_lane_values<R: ReduceOp>(results: Vec<ResultMsg>) -> Result<Vec<Vec<R::T>>> {
    results
        .into_iter()
        .enumerate()
        .map(|(lane, r)| {
            wire::decode_values::<R>(&r.payload)
                .with_context(|| format!("decoding lane {lane}'s reduced values"))
        })
        .collect()
}
