//! Mini-batch sub-gradient training over Sparse Allreduce (paper §I-A1,
//! §III-B "Mini-Batch Algorithm").
//!
//! The model is a multi-class linear classifier `W ∈ R^{F×C}` over a huge
//! sparse feature space, sharded across the cluster by the butterfly's
//! bottom-layer owner ranges (allreduce index = `hash(feature)·C + class`).
//! Every step, each worker:
//!
//! 1. samples a mini-batch whose active features follow the data's
//!    power-law;
//! 2. `config(out = previous batch's features, in = this batch's
//!    features)` — configs are dynamic, re-run every step exactly as in
//!    the paper's mini-batch pseudo-code;
//! 3. one `reduce` pushes the *previous* step's gradient down the
//!    butterfly (scatter-reduced into the persistent owner shards — the
//!    parameter-server bottom) and gathers fresh weights for the current
//!    batch back up (the paper's `in.values = reduce(out.values)`);
//! 4. computes loss and gradient on the gathered sub-model with a
//!    [`GradEngine`] — natively in Rust for tests, or through the AOT
//!    JAX/Pallas artifact via PJRT in production (`runtime::XlaGradEngine`).
//!
//! The one-step gradient delay is the paper's own semantics (push happens
//! before the next model fetch on the same indices).
//!
//! The per-worker state machine lives in [`SgdNode`]; every execution
//! mode drives the identical engine — [`Trainer`] holds all `m` nodes
//! over an in-process [`Session`] (lockstep or threaded), a
//! multi-process worker holds only its own node and drives it with its
//! transport-backed handle — so the per-worker final loss is the
//! cross-mode determinism probe.

use crate::comm::{ExecMode, Session};
use crate::partition::IndexHasher;
use crate::sparse::{IndexSet, SumF32};
use crate::util::{Pcg32, Zipf};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One sparse training example.
#[derive(Clone, Debug)]
pub struct Example {
    /// (feature id, value) pairs; feature ids are raw (un-hashed).
    pub feats: Vec<(i64, f32)>,
    pub label: u32,
}

/// Synthetic power-law classification data with a planted linear model.
#[derive(Clone, Debug)]
pub struct SynthData {
    pub features: i64,
    pub classes: usize,
    pub feats_per_example: usize,
    pub zipf_alpha: f64,
    zipf: Zipf,
}

impl SynthData {
    pub fn new(features: i64, classes: usize, feats_per_example: usize, zipf_alpha: f64) -> Self {
        Self {
            features,
            classes,
            feats_per_example,
            zipf_alpha,
            zipf: Zipf::new(features as u64, zipf_alpha),
        }
    }

    /// Planted ground-truth weight for (feature, class) — procedural, so
    /// the full `F×C` matrix is never materialized.
    pub fn true_weight(&self, feat: i64, class: usize) -> f32 {
        let mut z = (feat as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (class as u64) << 32;
        z ^= z >> 29;
        z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^= z >> 32;
        ((z as f64 / u64::MAX as f64) as f32 - 0.5) * 2.0
    }

    /// Sample one example: Zipf features, label = argmax of the planted
    /// model's logits (so the task is realizable).
    pub fn example(&self, rng: &mut Pcg32) -> Example {
        let mut feats: Vec<(i64, f32)> = Vec::with_capacity(self.feats_per_example);
        let mut seen = std::collections::HashSet::new();
        while feats.len() < self.feats_per_example {
            let f = self.zipf.sample(rng) as i64;
            if seen.insert(f) {
                feats.push((f, 1.0));
            }
        }
        feats.sort_unstable_by_key(|&(f, _)| f);
        let mut logits = vec![0f32; self.classes];
        for &(f, x) in &feats {
            for (c, l) in logits.iter_mut().enumerate() {
                *l += x * self.true_weight(f, c);
            }
        }
        let label = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        Example { feats, label }
    }

    pub fn batch(&self, rng: &mut Pcg32, size: usize) -> Vec<Example> {
        (0..size).map(|_| self.example(rng)).collect()
    }
}

/// A mini-batch densified against its active-feature dictionary.
#[derive(Clone, Debug)]
pub struct DenseBatch {
    /// Sorted distinct raw feature ids active in the batch.
    pub active: Vec<i64>,
    /// Row-major `[batch × active.len()]` feature values.
    pub x: Vec<f32>,
    pub labels: Vec<u32>,
}

impl DenseBatch {
    pub fn from_examples(examples: &[Example]) -> DenseBatch {
        let mut active: Vec<i64> =
            examples.iter().flat_map(|e| e.feats.iter().map(|&(f, _)| f)).collect();
        active.sort_unstable();
        active.dedup();
        let n = active.len();
        let mut x = vec![0f32; examples.len() * n];
        for (b, e) in examples.iter().enumerate() {
            for &(f, v) in &e.feats {
                let j = active.binary_search(&f).unwrap();
                x[b * n + j] = v;
            }
        }
        DenseBatch { active, x, labels: examples.iter().map(|e| e.label).collect() }
    }

    pub fn batch_size(&self) -> usize {
        self.labels.len()
    }
}

/// Computes loss and gradient of softmax cross-entropy for a densified
/// mini-batch against the gathered sub-model.
pub trait GradEngine {
    /// `w_sub` is row-major `[active × classes]`. Returns (mean loss,
    /// gradient of the same shape as `w_sub`).
    fn grad(&mut self, batch: &DenseBatch, w_sub: &[f32], classes: usize) -> (f32, Vec<f32>);
}

/// Pure-Rust reference engine (the test oracle; production uses the
/// JAX/Pallas AOT artifact through `runtime::XlaGradEngine`, which must
/// agree with this to 1e-4).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeGradEngine;

impl GradEngine for NativeGradEngine {
    fn grad(&mut self, batch: &DenseBatch, w_sub: &[f32], classes: usize) -> (f32, Vec<f32>) {
        let n = batch.active.len();
        let bsz = batch.batch_size();
        assert_eq!(w_sub.len(), n * classes);
        let mut loss = 0f32;
        let mut grad = vec![0f32; n * classes];
        let mut logits = vec![0f32; classes];
        let mut probs = vec![0f32; classes];
        for b in 0..bsz {
            let xrow = &batch.x[b * n..(b + 1) * n];
            // logits = x · W
            logits.iter_mut().for_each(|l| *l = 0.0);
            for (j, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w_sub[j * classes..(j + 1) * classes];
                    for (l, &w) in logits.iter_mut().zip(wrow) {
                        *l += xv * w;
                    }
                }
            }
            // stable softmax
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for (p, &l) in probs.iter_mut().zip(&logits) {
                *p = (l - maxl).exp();
                z += *p;
            }
            probs.iter_mut().for_each(|p| *p /= z);
            let y = batch.labels[b] as usize;
            loss += -(probs[y].max(1e-12)).ln();
            // grad += x^T (p - onehot(y))
            for (j, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let grow = &mut grad[j * classes..(j + 1) * classes];
                    for (c, g) in grow.iter_mut().enumerate() {
                        let ind = if c == y { 1.0 } else { 0.0 };
                        *g += xv * (probs[c] - ind);
                    }
                }
            }
        }
        let inv = 1.0 / bsz as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        (loss * inv, grad)
    }
}

/// Trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    pub classes: usize,
    pub batch_per_worker: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self { classes: 8, batch_per_worker: 32, lr: 0.5, seed: 123 }
    }
}

/// Alignment between a raw active-feature dictionary and the sorted,
/// hash-permuted allreduce index space.
#[derive(Clone, Debug, Default)]
struct ExpandMap {
    /// Sorted expanded allreduce indices (`hash(feat)·C + class`).
    indices: Vec<i64>,
    /// `order[jj]` = raw-dictionary position of the jj-th hashed feature.
    order: Vec<usize>,
    classes: usize,
}

impl ExpandMap {
    /// Reorder row-major `[active × classes]` values into expanded-index
    /// order (for pushing gradients).
    fn scatter(&self, row_major: &[f32]) -> Vec<f32> {
        let c = self.classes;
        let mut out = Vec::with_capacity(self.indices.len());
        for &j in &self.order {
            out.extend_from_slice(&row_major[j * c..(j + 1) * c]);
        }
        out
    }

    /// Inverse of [`Self::scatter`]: expanded-order values back to
    /// row-major `[active × classes]` (for gathered weights).
    fn gather(&self, expanded: &[f32]) -> Vec<f32> {
        let c = self.classes;
        let mut out = vec![0f32; expanded.len()];
        for (jj, &j) in self.order.iter().enumerate() {
            out[j * c..(j + 1) * c].copy_from_slice(&expanded[jj * c..(jj + 1) * c]);
        }
        out
    }
}

/// One worker's share of a distributed SGD run: its RNG stream, its
/// persistent bottom-owner model shard, the one-step-delayed gradient
/// push, and the current batch's expansion map. Deterministic in
/// `(cfg.seed, node)` — a multi-process worker rebuilding only its node
/// samples the identical batches as lane `node` of an in-process run.
pub struct SgdNode<E: GradEngine> {
    data: Arc<SynthData>,
    cfg: SgdConfig,
    hasher: IndexHasher,
    rng: Pcg32,
    engine: E,
    /// Persistent model shard (bottom owner): allreduce index → weight.
    /// Shared with the bottom transform closure, which may run on a lane
    /// thread in threaded mode.
    shard: Arc<Mutex<HashMap<i64, f32>>>,
    /// Previous step's (expanded indices, expanded-order gradient).
    pending: (Vec<i64>, Vec<f32>),
    cur: Option<(DenseBatch, ExpandMap)>,
    /// Per-step loss on this worker's batches.
    pub losses: Vec<f32>,
}

impl<E: GradEngine> SgdNode<E> {
    /// Build worker `node` of `m`. The RNG forks are drawn from one root
    /// sequence, so building node `w` standalone replays the forks a
    /// whole-cluster build would have made before it.
    pub fn new(node: usize, data: Arc<SynthData>, cfg: SgdConfig, engine: E) -> SgdNode<E> {
        let hasher = IndexHasher::new(data.features as u64, cfg.seed ^ 0xFEA7);
        let mut root = Pcg32::new(cfg.seed);
        let mut rng = root.fork(0);
        for i in 1..=node {
            rng = root.fork(i as u64);
        }
        SgdNode {
            data,
            cfg,
            hasher,
            rng,
            engine,
            shard: Arc::new(Mutex::new(HashMap::new())),
            pending: (Vec::new(), Vec::new()),
            cur: None,
            losses: Vec::new(),
        }
    }

    /// The allreduce index domain: `features × classes`.
    pub fn index_range(&self) -> i64 {
        self.data.features * self.cfg.classes as i64
    }

    /// Expansion of a sorted raw active-feature list into sorted hashed
    /// per-class allreduce indices, plus the permutation needed to align
    /// row-major `[active × classes]` values with that sorted index list.
    fn expand(&self, feats: &[i64]) -> ExpandMap {
        let c = self.cfg.classes;
        let hashed: Vec<i64> = feats.iter().map(|&f| self.hasher.hash(f)).collect();
        let mut order: Vec<usize> = (0..feats.len()).collect();
        order.sort_unstable_by_key(|&j| hashed[j]);
        let mut indices = Vec::with_capacity(feats.len() * c);
        for &j in &order {
            for cls in 0..c as i64 {
                indices.push(hashed[j] * c as i64 + cls);
            }
        }
        ExpandMap { indices, order, classes: c }
    }

    /// Start one step: sample this worker's batch and return
    /// `(outbound, inbound, push_values)` for the dynamic config —
    /// outbound = the previous step's gradient indices, inbound = this
    /// batch's class-expanded features.
    pub fn begin_step(&mut self) -> (IndexSet, IndexSet, Vec<f32>) {
        let exs = self.data.batch(&mut self.rng, self.cfg.batch_per_worker);
        let batch = DenseBatch::from_examples(&exs);
        let map = self.expand(&batch.active);
        let outbound = IndexSet::from_sorted(self.pending.0.clone());
        let inbound = IndexSet::from_sorted(map.indices.clone());
        let push = self.pending.1.clone();
        self.cur = Some((batch, map));
        (outbound, inbound, push)
    }

    /// The parameter-server bottom transform for this step: fold the
    /// reduced gradient into the owned shard, serve fresh weights for
    /// the requested indices. Runs on whatever thread executes the
    /// node's bottom (lane thread in threaded mode), hence `Send`.
    pub fn bottom_fn(
        &self,
    ) -> impl FnOnce(&IndexSet, &[f32], &IndexSet) -> Vec<f32> + Send + 'static {
        let shard = self.shard.clone();
        let lr = self.cfg.lr;
        move |down: &IndexSet, reduced: &[f32], up: &IndexSet| {
            let mut s = shard.lock().expect("model shard poisoned");
            for (&idx, &g) in down.as_slice().iter().zip(reduced) {
                *s.entry(idx).or_insert(0.0) -= lr * g;
            }
            up.as_slice().iter().map(|i| *s.get(i).unwrap_or(&0.0)).collect::<Vec<f32>>()
        }
    }

    /// Finish the step: compute loss + gradient on the gathered
    /// sub-model and queue the gradient for the next step's push.
    pub fn finish_step(&mut self, gathered: Vec<f32>) -> f32 {
        let (batch, map) = self.cur.take().expect("begin_step before finish_step");
        let w_sub = map.gather(&gathered);
        let (loss, grad) = self.engine.grad(&batch, &w_sub, self.cfg.classes);
        self.pending = (map.indices.clone(), map.scatter(&grad));
        self.losses.push(loss);
        loss
    }

    /// The cross-mode determinism probe: this worker's final loss.
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(0.0)
    }

    /// Current weight of an allreduce index, if this node owns it.
    pub fn weight_of(&self, idx: i64) -> Option<f32> {
        self.shard.lock().expect("model shard poisoned").get(&idx).copied()
    }

    /// Live parameters in this node's shard.
    pub fn live_params(&self) -> usize {
        self.shard.lock().expect("model shard poisoned").len()
    }
}

/// One global SGD step across all in-process nodes: dynamic config, one
/// parameter-server reduce, then per-worker gradient computation.
/// Returns the mean loss. Shared by [`Trainer`] and the comm-session
/// job runner, so there is exactly one driver-side step implementation.
pub(crate) fn sgd_step<E: GradEngine>(
    session: &mut Session,
    nodes: &mut [SgdNode<E>],
) -> Result<f32> {
    let m = nodes.len();
    let mut outs = Vec::with_capacity(m);
    let mut ins = Vec::with_capacity(m);
    let mut vals = Vec::with_capacity(m);
    for node in nodes.iter_mut() {
        let (o, i, v) = node.begin_step();
        outs.push(o);
        ins.push(i);
        vals.push(v);
    }
    let bottoms: Vec<_> = nodes.iter().map(|n| n.bottom_fn()).collect();
    let mut handle = session.configure(outs, ins)?;
    let weights = handle.allreduce_with_bottom::<SumF32, _>(vals, bottoms)?;
    drop(handle);
    let mut mean = 0f32;
    for (node, w) in nodes.iter_mut().zip(weights) {
        mean += node.finish_step(w);
    }
    Ok(mean / m as f32)
}

/// Distributed mini-batch SGD trainer: all `m` workers' [`SgdNode`]s
/// driven through one in-process communicator [`Session`].
pub struct Trainer<E: GradEngine> {
    session: Session,
    nodes: Vec<SgdNode<E>>,
    hasher: IndexHasher,
    cfg: SgdConfig,
    pub losses: Vec<f32>,
    pub step_count: usize,
}

impl<E: GradEngine> Trainer<E> {
    /// Lockstep trainer (the deterministic oracle; historical default).
    /// `features` is the raw feature-space size; the allreduce index
    /// range is `features · classes`.
    pub fn new(degrees: Vec<usize>, data: SynthData, cfg: SgdConfig, engines: Vec<E>) -> Self {
        Self::with_mode(degrees, data, cfg, engines, ExecMode::Lockstep)
            .expect("in-process sgd session failed")
    }

    /// Trainer over any in-process execution mode (lockstep | threaded).
    pub fn with_mode(
        degrees: Vec<usize>,
        data: SynthData,
        cfg: SgdConfig,
        engines: Vec<E>,
        mode: ExecMode,
    ) -> Result<Self> {
        let m: usize = degrees.iter().product();
        assert_eq!(engines.len(), m);
        let data = Arc::new(data);
        let range = data.features * cfg.classes as i64;
        let session = Session::new_in_process(mode, degrees, 4, range, None)?;
        let hasher = IndexHasher::new(data.features as u64, cfg.seed ^ 0xFEA7);
        let nodes: Vec<SgdNode<E>> = engines
            .into_iter()
            .enumerate()
            .map(|(w, engine)| SgdNode::new(w, data.clone(), cfg, engine))
            .collect();
        Ok(Self { session, nodes, hasher, cfg, losses: Vec::new(), step_count: 0 })
    }

    pub fn machines(&self) -> usize {
        self.nodes.len()
    }

    /// Run one global training step. Returns mean loss across workers.
    pub fn step(&mut self) -> f32 {
        let mean =
            sgd_step(&mut self.session, &mut self.nodes).expect("in-process sgd step failed");
        self.losses.push(mean);
        self.step_count += 1;
        mean
    }

    /// Per-worker nodes (final-loss probes, shard inspection).
    pub fn nodes(&self) -> &[SgdNode<E>] {
        &self.nodes
    }

    /// Sum of per-worker final losses — the cross-mode determinism probe
    /// multi-process runs report per worker and sum coordinator-side.
    pub fn checksum(&self) -> f64 {
        self.nodes.iter().map(|n| n.final_loss() as f64).sum()
    }

    /// Current weight of a (feature, class) pair, reading the owner shard.
    pub fn weight(&self, feat: i64, class: usize) -> f32 {
        let idx = self.hasher.hash(feat) * self.cfg.classes as i64 + class as i64;
        for node in &self.nodes {
            if let Some(w) = node.weight_of(idx) {
                return w;
            }
        }
        0.0
    }

    /// Total parameters touched so far (live entries across shards).
    pub fn live_params(&self) -> usize {
        self.nodes.iter().map(|n| n.live_params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean(xs: &[f32]) -> f32 {
        xs.iter().sum::<f32>() / xs.len() as f32
    }

    #[test]
    fn dense_batch_construction() {
        let exs = vec![
            Example { feats: vec![(3, 1.0), (7, 2.0)], label: 0 },
            Example { feats: vec![(7, 1.0)], label: 1 },
        ];
        let b = DenseBatch::from_examples(&exs);
        assert_eq!(b.active, vec![3, 7]);
        assert_eq!(b.x, vec![1.0, 2.0, 0.0, 1.0]);
        assert_eq!(b.labels, vec![0, 1]);
    }

    #[test]
    fn native_grad_matches_finite_differences() {
        let mut rng = Pcg32::new(2);
        let data = SynthData::new(50, 4, 5, 1.1);
        let exs = data.batch(&mut rng, 6);
        let batch = DenseBatch::from_examples(&exs);
        let n = batch.active.len();
        let c = 4usize;
        let w: Vec<f32> = (0..n * c).map(|_| rng.next_f32() - 0.5).collect();
        let mut engine = NativeGradEngine;
        let (_, grad) = engine.grad(&batch, &w, c);
        let eps = 1e-3f32;
        for probe in [0usize, n * c / 2, n * c - 1] {
            let mut wp = w.clone();
            wp[probe] += eps;
            let (lp, _) = engine.grad(&batch, &wp, c);
            let mut wm = w.clone();
            wm[probe] -= eps;
            let (lm, _) = engine.grad(&batch, &wm, c);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[probe]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {probe}: fd {fd} vs grad {}",
                grad[probe]
            );
        }
    }

    #[test]
    fn loss_decreases_single_machine() {
        let data = SynthData::new(200, 4, 6, 1.05);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 64, lr: 1.5, seed: 7 };
        let mut t = Trainer::new(vec![1], data, cfg, vec![NativeGradEngine]);
        for _ in 0..200 {
            t.step();
        }
        let early = mean(&t.losses[1..6]);
        let late = mean(&t.losses[195..200]);
        assert!(
            late < early * 0.7,
            "loss did not decrease: early {early:.4} late {late:.4}"
        );
    }

    #[test]
    fn loss_decreases_distributed() {
        let data = SynthData::new(200, 4, 6, 1.05);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 32, lr: 1.0, seed: 8 };
        let mut t = Trainer::new(
            vec![2, 2],
            data,
            cfg,
            vec![NativeGradEngine; 4],
        );
        for _ in 0..200 {
            t.step();
        }
        let early = mean(&t.losses[1..6]);
        let late = mean(&t.losses[195..200]);
        assert!(
            late < early * 0.7,
            "distributed loss did not decrease: early {early:.4} late {late:.4}"
        );
        assert!(t.live_params() > 0);
    }

    #[test]
    fn threaded_trainer_matches_lockstep_bit_for_bit() {
        let cfg = SgdConfig { classes: 4, batch_per_worker: 8, lr: 0.5, seed: 21 };
        let mut a = Trainer::with_mode(
            vec![2, 2],
            SynthData::new(300, 4, 6, 1.1),
            cfg,
            vec![NativeGradEngine; 4],
            ExecMode::Lockstep,
        )
        .unwrap();
        let mut b = Trainer::with_mode(
            vec![2, 2],
            SynthData::new(300, 4, 6, 1.1),
            cfg,
            vec![NativeGradEngine; 4],
            ExecMode::Threaded,
        )
        .unwrap();
        for _ in 0..8 {
            let la = a.step();
            let lb = b.step();
            assert_eq!(la.to_bits(), lb.to_bits(), "per-step mean loss must be identical");
        }
        assert_eq!(a.checksum(), b.checksum());
        assert!(a.checksum().is_finite());
    }

    #[test]
    fn standalone_node_matches_trainer_lane() {
        // A multi-process worker builds only its own SgdNode; its batch
        // stream must equal the corresponding lane of a full build.
        let cfg = SgdConfig { classes: 4, batch_per_worker: 4, lr: 0.2, seed: 33 };
        let data = Arc::new(SynthData::new(120, 4, 5, 1.1));
        let mut full: Vec<SgdNode<NativeGradEngine>> = (0..4)
            .map(|w| SgdNode::new(w, data.clone(), cfg, NativeGradEngine))
            .collect();
        let mut lone = SgdNode::new(2, data.clone(), cfg, NativeGradEngine);
        let (o_full, i_full, v_full) = full[2].begin_step();
        let (o_lone, i_lone, v_lone) = lone.begin_step();
        assert_eq!(o_full.as_slice(), o_lone.as_slice());
        assert_eq!(i_full.as_slice(), i_lone.as_slice());
        assert_eq!(v_full, v_lone);
    }

    #[test]
    fn model_shards_are_disjoint() {
        let data = SynthData::new(300, 4, 6, 1.1);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 8, lr: 0.2, seed: 9 };
        let mut t = Trainer::new(vec![4], data, cfg, vec![NativeGradEngine; 4]);
        for _ in 0..5 {
            t.step();
        }
        let mut seen = std::collections::HashSet::new();
        for node in t.nodes() {
            let shard = node.shard.lock().unwrap();
            for &k in shard.keys() {
                assert!(seen.insert(k), "index {k} owned by two shards");
            }
        }
    }

    #[test]
    fn synth_labels_are_realizable() {
        // the planted model classifies its own samples consistently
        let data = SynthData::new(200, 4, 5, 1.2);
        let mut rng = Pcg32::new(4);
        let e1 = data.example(&mut rng);
        // recompute label from true weights
        let mut logits = vec![0f32; 4];
        for &(f, x) in &e1.feats {
            for (c, l) in logits.iter_mut().enumerate() {
                *l += x * data.true_weight(f, c);
            }
        }
        let argmax =
            logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax as u32, e1.label);
    }
}
