//! Mini-batch sub-gradient training over Sparse Allreduce (paper §I-A1,
//! §III-B "Mini-Batch Algorithm").
//!
//! The model is a multi-class linear classifier `W ∈ R^{F×C}` over a huge
//! sparse feature space, sharded across the cluster by the butterfly's
//! bottom-layer owner ranges (allreduce index = `hash(feature)·C + class`).
//! Every step, each worker:
//!
//! 1. samples a mini-batch whose active features follow the data's
//!    power-law;
//! 2. `config(out = previous batch's features, in = this batch's
//!    features)` — configs are dynamic, re-run every step exactly as in
//!    the paper's mini-batch pseudo-code;
//! 3. one `reduce` pushes the *previous* step's gradient down the
//!    butterfly (scatter-reduced into the persistent owner shards — the
//!    parameter-server bottom) and gathers fresh weights for the current
//!    batch back up (the paper's `in.values = reduce(out.values)`);
//! 4. computes loss and gradient on the gathered sub-model with a
//!    [`GradEngine`] — natively in Rust for tests, or through the AOT
//!    JAX/Pallas artifact via PJRT in production (`runtime::XlaGradEngine`).
//!
//! The one-step gradient delay is the paper's own semantics (push happens
//! before the next model fetch on the same indices).

use crate::allreduce::LocalCluster;
use crate::partition::IndexHasher;
use crate::sparse::{IndexSet, SumF32};
use crate::topology::Butterfly;
use crate::util::{Pcg32, Zipf};
use std::collections::HashMap;

/// One sparse training example.
#[derive(Clone, Debug)]
pub struct Example {
    /// (feature id, value) pairs; feature ids are raw (un-hashed).
    pub feats: Vec<(i64, f32)>,
    pub label: u32,
}

/// Synthetic power-law classification data with a planted linear model.
#[derive(Clone, Debug)]
pub struct SynthData {
    pub features: i64,
    pub classes: usize,
    pub feats_per_example: usize,
    pub zipf_alpha: f64,
    zipf: Zipf,
}

impl SynthData {
    pub fn new(features: i64, classes: usize, feats_per_example: usize, zipf_alpha: f64) -> Self {
        Self {
            features,
            classes,
            feats_per_example,
            zipf_alpha,
            zipf: Zipf::new(features as u64, zipf_alpha),
        }
    }

    /// Planted ground-truth weight for (feature, class) — procedural, so
    /// the full `F×C` matrix is never materialized.
    pub fn true_weight(&self, feat: i64, class: usize) -> f32 {
        let mut z = (feat as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (class as u64) << 32;
        z ^= z >> 29;
        z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^= z >> 32;
        ((z as f64 / u64::MAX as f64) as f32 - 0.5) * 2.0
    }

    /// Sample one example: Zipf features, label = argmax of the planted
    /// model's logits (so the task is realizable).
    pub fn example(&self, rng: &mut Pcg32) -> Example {
        let mut feats: Vec<(i64, f32)> = Vec::with_capacity(self.feats_per_example);
        let mut seen = std::collections::HashSet::new();
        while feats.len() < self.feats_per_example {
            let f = self.zipf.sample(rng) as i64;
            if seen.insert(f) {
                feats.push((f, 1.0));
            }
        }
        feats.sort_unstable_by_key(|&(f, _)| f);
        let mut logits = vec![0f32; self.classes];
        for &(f, x) in &feats {
            for (c, l) in logits.iter_mut().enumerate() {
                *l += x * self.true_weight(f, c);
            }
        }
        let label = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        Example { feats, label }
    }

    pub fn batch(&self, rng: &mut Pcg32, size: usize) -> Vec<Example> {
        (0..size).map(|_| self.example(rng)).collect()
    }
}

/// A mini-batch densified against its active-feature dictionary.
#[derive(Clone, Debug)]
pub struct DenseBatch {
    /// Sorted distinct raw feature ids active in the batch.
    pub active: Vec<i64>,
    /// Row-major `[batch × active.len()]` feature values.
    pub x: Vec<f32>,
    pub labels: Vec<u32>,
}

impl DenseBatch {
    pub fn from_examples(examples: &[Example]) -> DenseBatch {
        let mut active: Vec<i64> =
            examples.iter().flat_map(|e| e.feats.iter().map(|&(f, _)| f)).collect();
        active.sort_unstable();
        active.dedup();
        let n = active.len();
        let mut x = vec![0f32; examples.len() * n];
        for (b, e) in examples.iter().enumerate() {
            for &(f, v) in &e.feats {
                let j = active.binary_search(&f).unwrap();
                x[b * n + j] = v;
            }
        }
        DenseBatch { active, x, labels: examples.iter().map(|e| e.label).collect() }
    }

    pub fn batch_size(&self) -> usize {
        self.labels.len()
    }
}

/// Computes loss and gradient of softmax cross-entropy for a densified
/// mini-batch against the gathered sub-model.
pub trait GradEngine {
    /// `w_sub` is row-major `[active × classes]`. Returns (mean loss,
    /// gradient of the same shape as `w_sub`).
    fn grad(&mut self, batch: &DenseBatch, w_sub: &[f32], classes: usize) -> (f32, Vec<f32>);
}

/// Pure-Rust reference engine (the test oracle; production uses the
/// JAX/Pallas AOT artifact through `runtime::XlaGradEngine`, which must
/// agree with this to 1e-4).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeGradEngine;

impl GradEngine for NativeGradEngine {
    fn grad(&mut self, batch: &DenseBatch, w_sub: &[f32], classes: usize) -> (f32, Vec<f32>) {
        let n = batch.active.len();
        let bsz = batch.batch_size();
        assert_eq!(w_sub.len(), n * classes);
        let mut loss = 0f32;
        let mut grad = vec![0f32; n * classes];
        let mut logits = vec![0f32; classes];
        let mut probs = vec![0f32; classes];
        for b in 0..bsz {
            let xrow = &batch.x[b * n..(b + 1) * n];
            // logits = x · W
            logits.iter_mut().for_each(|l| *l = 0.0);
            for (j, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let wrow = &w_sub[j * classes..(j + 1) * classes];
                    for (l, &w) in logits.iter_mut().zip(wrow) {
                        *l += xv * w;
                    }
                }
            }
            // stable softmax
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for (p, &l) in probs.iter_mut().zip(&logits) {
                *p = (l - maxl).exp();
                z += *p;
            }
            probs.iter_mut().for_each(|p| *p /= z);
            let y = batch.labels[b] as usize;
            loss += -(probs[y].max(1e-12)).ln();
            // grad += x^T (p - onehot(y))
            for (j, &xv) in xrow.iter().enumerate() {
                if xv != 0.0 {
                    let grow = &mut grad[j * classes..(j + 1) * classes];
                    for (c, g) in grow.iter_mut().enumerate() {
                        let ind = if c == y { 1.0 } else { 0.0 };
                        *g += xv * (probs[c] - ind);
                    }
                }
            }
        }
        let inv = 1.0 / bsz as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        (loss * inv, grad)
    }
}

/// Trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    pub classes: usize,
    pub batch_per_worker: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self { classes: 8, batch_per_worker: 32, lr: 0.5, seed: 123 }
    }
}

/// Alignment between a raw active-feature dictionary and the sorted,
/// hash-permuted allreduce index space.
#[derive(Clone, Debug, Default)]
struct ExpandMap {
    /// Sorted expanded allreduce indices (`hash(feat)·C + class`).
    indices: Vec<i64>,
    /// `order[jj]` = raw-dictionary position of the jj-th hashed feature.
    order: Vec<usize>,
    classes: usize,
}

impl ExpandMap {
    /// Reorder row-major `[active × classes]` values into expanded-index
    /// order (for pushing gradients).
    fn scatter(&self, row_major: &[f32]) -> Vec<f32> {
        let c = self.classes;
        let mut out = Vec::with_capacity(self.indices.len());
        for &j in &self.order {
            out.extend_from_slice(&row_major[j * c..(j + 1) * c]);
        }
        out
    }

    /// Inverse of [`Self::scatter`]: expanded-order values back to
    /// row-major `[active × classes]` (for gathered weights).
    fn gather(&self, expanded: &[f32]) -> Vec<f32> {
        let c = self.classes;
        let mut out = vec![0f32; expanded.len()];
        for (jj, &j) in self.order.iter().enumerate() {
            out[j * c..(j + 1) * c].copy_from_slice(&expanded[jj * c..(jj + 1) * c]);
        }
        out
    }
}

/// Distributed mini-batch SGD trainer (sequential lockstep driver).
pub struct Trainer<E: GradEngine> {
    cluster: LocalCluster,
    engines: Vec<E>,
    data: SynthData,
    cfg: SgdConfig,
    hasher: IndexHasher,
    rngs: Vec<Pcg32>,
    /// Persistent model shards: bottom owner → (allreduce index → weight).
    shards: Vec<HashMap<i64, f32>>,
    /// Per worker: previous step's (expanded indices, expanded-order grad).
    pending_push: Vec<(Vec<i64>, Vec<f32>)>,
    pub losses: Vec<f32>,
    pub step_count: usize,
}

impl<E: GradEngine> Trainer<E> {
    /// `features` is the raw feature-space size; allreduce index range is
    /// `features · classes`.
    pub fn new(degrees: Vec<usize>, data: SynthData, cfg: SgdConfig, engines: Vec<E>) -> Self {
        let m: usize = degrees.iter().product();
        assert_eq!(engines.len(), m);
        let range = data.features * data.classes as i64;
        let topo = Butterfly::new(degrees, range);
        let cluster = LocalCluster::new(topo);
        let hasher = IndexHasher::new(data.features as u64, cfg.seed ^ 0xFEA7);
        let mut root = Pcg32::new(cfg.seed);
        let rngs = (0..m).map(|i| root.fork(i as u64)).collect();
        Self {
            cluster,
            engines,
            data,
            cfg,
            hasher,
            rngs,
            shards: (0..m).map(|_| HashMap::new()).collect(),
            pending_push: (0..m).map(|_| (Vec::new(), Vec::new())).collect(),
            losses: Vec::new(),
            step_count: 0,
        }
    }

    pub fn machines(&self) -> usize {
        self.engines.len()
    }

    /// Expansion of a sorted raw active-feature list into sorted hashed
    /// per-class allreduce indices, plus the permutation needed to align
    /// row-major `[active × classes]` values with that sorted index list.
    fn expand(&self, feats: &[i64]) -> ExpandMap {
        let c = self.cfg.classes;
        let hashed: Vec<i64> = feats.iter().map(|&f| self.hasher.hash(f)).collect();
        let mut order: Vec<usize> = (0..feats.len()).collect();
        order.sort_unstable_by_key(|&j| hashed[j]);
        let mut indices = Vec::with_capacity(feats.len() * c);
        for &j in &order {
            for cls in 0..c as i64 {
                indices.push(hashed[j] * c as i64 + cls);
            }
        }
        ExpandMap { indices, order, classes: c }
    }

    /// Run one global training step. Returns mean loss across workers.
    pub fn step(&mut self) -> f32 {
        let m = self.machines();
        // 1. sample batches + densify
        let batches: Vec<DenseBatch> = (0..m)
            .map(|w| {
                let exs = self.data.batch(&mut self.rngs[w], self.cfg.batch_per_worker);
                DenseBatch::from_examples(&exs)
            })
            .collect();

        // 2. dynamic config: outbound = last step's gradient indices,
        //    inbound = this step's active features (both class-expanded).
        let maps: Vec<ExpandMap> = batches.iter().map(|b| self.expand(&b.active)).collect();
        let outbound: Vec<IndexSet> = self
            .pending_push
            .iter()
            .map(|(idx, _)| IndexSet::from_sorted(idx.clone()))
            .collect();
        let inbound: Vec<IndexSet> =
            maps.iter().map(|m| IndexSet::from_sorted(m.indices.clone())).collect();
        self.cluster.config(outbound, inbound);

        // 3. one reduce: push pending gradients into the owner shards,
        //    pull fresh weights for the current batches.
        let push_values: Vec<Vec<f32>> =
            self.pending_push.iter().map(|(_, v)| v.clone()).collect();
        let shards = &mut self.shards;
        let lr = self.cfg.lr;
        let cluster = &self.cluster;
        let (weights, _trace) = cluster.reduce_with_bottom::<SumF32, _>(push_values, |node, reduced| {
            let down = cluster.node(node).bottom_down_set();
            let up = cluster.node(node).bottom_up_set();
            let shard = &mut shards[node];
            for (&idx, &g) in down.as_slice().iter().zip(reduced) {
                *shard.entry(idx).or_insert(0.0) -= lr * g;
            }
            up.as_slice().iter().map(|i| *shard.get(i).unwrap_or(&0.0)).collect()
        });

        // 4. compute gradients on the gathered sub-models
        let mut mean_loss = 0f32;
        for w in 0..m {
            let w_sub = maps[w].gather(&weights[w]);
            let (loss, grad) = self.engines[w].grad(&batches[w], &w_sub, self.cfg.classes);
            mean_loss += loss;
            self.pending_push[w] = (maps[w].indices.clone(), maps[w].scatter(&grad));
        }
        mean_loss /= m as f32;
        self.losses.push(mean_loss);
        self.step_count += 1;
        mean_loss
    }

    /// Current weight of a (feature, class) pair, reading the owner shard.
    pub fn weight(&self, feat: i64, class: usize) -> f32 {
        let idx = self.hasher.hash(feat) * self.cfg.classes as i64 + class as i64;
        for shard in &self.shards {
            if let Some(&w) = shard.get(&idx) {
                return w;
            }
        }
        0.0
    }

    /// Total parameters touched so far (live entries across shards).
    pub fn live_params(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean(xs: &[f32]) -> f32 {
        xs.iter().sum::<f32>() / xs.len() as f32
    }

    #[test]
    fn dense_batch_construction() {
        let exs = vec![
            Example { feats: vec![(3, 1.0), (7, 2.0)], label: 0 },
            Example { feats: vec![(7, 1.0)], label: 1 },
        ];
        let b = DenseBatch::from_examples(&exs);
        assert_eq!(b.active, vec![3, 7]);
        assert_eq!(b.x, vec![1.0, 2.0, 0.0, 1.0]);
        assert_eq!(b.labels, vec![0, 1]);
    }

    #[test]
    fn native_grad_matches_finite_differences() {
        let mut rng = Pcg32::new(2);
        let data = SynthData::new(50, 4, 5, 1.1);
        let exs = data.batch(&mut rng, 6);
        let batch = DenseBatch::from_examples(&exs);
        let n = batch.active.len();
        let c = 4usize;
        let w: Vec<f32> = (0..n * c).map(|_| rng.next_f32() - 0.5).collect();
        let mut engine = NativeGradEngine;
        let (_, grad) = engine.grad(&batch, &w, c);
        let eps = 1e-3f32;
        for probe in [0usize, n * c / 2, n * c - 1] {
            let mut wp = w.clone();
            wp[probe] += eps;
            let (lp, _) = engine.grad(&batch, &wp, c);
            let mut wm = w.clone();
            wm[probe] -= eps;
            let (lm, _) = engine.grad(&batch, &wm, c);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[probe]).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {probe}: fd {fd} vs grad {}",
                grad[probe]
            );
        }
    }

    #[test]
    fn loss_decreases_single_machine() {
        let data = SynthData::new(200, 4, 6, 1.05);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 64, lr: 1.5, seed: 7 };
        let mut t = Trainer::new(vec![1], data, cfg, vec![NativeGradEngine]);
        for _ in 0..200 {
            t.step();
        }
        let early = mean(&t.losses[1..6]);
        let late = mean(&t.losses[195..200]);
        assert!(
            late < early * 0.7,
            "loss did not decrease: early {early:.4} late {late:.4}"
        );
    }

    #[test]
    fn loss_decreases_distributed() {
        let data = SynthData::new(200, 4, 6, 1.05);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 32, lr: 1.0, seed: 8 };
        let mut t = Trainer::new(
            vec![2, 2],
            data,
            cfg,
            vec![NativeGradEngine; 4],
        );
        for _ in 0..200 {
            t.step();
        }
        let early = mean(&t.losses[1..6]);
        let late = mean(&t.losses[195..200]);
        assert!(
            late < early * 0.7,
            "distributed loss did not decrease: early {early:.4} late {late:.4}"
        );
        assert!(t.live_params() > 0);
    }

    #[test]
    fn model_shards_are_disjoint() {
        let data = SynthData::new(300, 4, 6, 1.1);
        let cfg = SgdConfig { classes: 4, batch_per_worker: 8, lr: 0.2, seed: 9 };
        let mut t = Trainer::new(vec![4], data, cfg, vec![NativeGradEngine; 4]);
        for _ in 0..5 {
            t.step();
        }
        let mut seen = std::collections::HashSet::new();
        for shard in &t.shards {
            for &k in shard.keys() {
                assert!(seen.insert(k), "index {k} owned by two shards");
            }
        }
    }

    #[test]
    fn synth_labels_are_realizable() {
        // the planted model classifies its own samples consistently
        let data = SynthData::new(200, 4, 5, 1.2);
        let mut rng = Pcg32::new(4);
        let e1 = data.example(&mut rng);
        // recompute label from true weights
        let mut logits = vec![0f32; 4];
        for &(f, x) in &e1.feats {
            for (c, l) in logits.iter_mut().enumerate() {
                *l += x * data.true_weight(f, c);
            }
        }
        let argmax =
            logits.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax as u32, e1.label);
    }
}
