//! Distributed PageRank over Sparse Allreduce (paper §I-A2, §VI-E).
//!
//! Edges are random-partitioned across machines; each machine holds a
//! shard CSR. One iteration is: local SpMV `Qᵢ = Gᵢ·Pᵢ`, then one sparse
//! sum-allreduce contributing `Qᵢ` (outbound = local destination vertices)
//! and collecting fresh `P` values (inbound = local source vertices),
//! finishing with the teleport update `P' = 1/n + (n−1)/n · Q` (paper
//! eq. 2). The graph is static, so config runs exactly once.

use crate::allreduce::{LocalCluster, Trace};
use crate::graph::{Csr, EdgeList};
use crate::partition::{random_edge_partition, IndexHasher};
use crate::sparse::{IndexSet, SumF32};
use crate::topology::Butterfly;

/// PageRank run parameters.
#[derive(Clone, Copy, Debug)]
pub struct PageRankConfig {
    /// Butterfly degree schedule (product = machine count).
    pub seed: u64,
    pub iters: usize,
}

impl Default for PageRankConfig {
    fn default() -> Self {
        Self { seed: 42, iters: 10 }
    }
}

/// The paper's eq. 2 teleport update, `P' = 1/n + (n−1)/n · Q`, applied
/// in place. Every driver — lockstep oracle, comm-session lanes,
/// multi-process workers — MUST share this one function: a divergent
/// float-op order would silently break the cross-mode checksum equality
/// the test suite anchors on.
pub fn apply_update(p: &mut [f32], sums: &[f32], vertices: i64) {
    let teleport = 1.0f32 / vertices as f32;
    let damp = (vertices as f32 - 1.0) / vertices as f32;
    for (pv, s) in p.iter_mut().zip(sums) {
        *pv = teleport + damp * s;
    }
}

/// The uniform starting vector (`1/n` per tracked source vertex).
pub fn initial_p(vertices: i64, cols: usize) -> Vec<f32> {
    vec![1.0f32 / vertices as f32; cols]
}

/// Serial oracle: dense PageRank with the paper's update rule.
/// Returns scores indexed by vertex id.
pub fn serial_pagerank(graph: &EdgeList, iters: usize) -> Vec<f32> {
    let n = graph.vertices as usize;
    let outdeg = graph.out_degrees();
    let teleport = 1.0f32 / n as f32;
    let damp = (n as f32 - 1.0) / n as f32;
    let mut p = vec![teleport; n];
    for _ in 0..iters {
        let mut q = vec![0f32; n];
        for &(u, v) in &graph.edges {
            let w = 1.0 / outdeg[u as usize] as f32;
            q[v as usize] += w * p[u as usize];
        }
        for (pv, qv) in p.iter_mut().zip(&q) {
            *pv = teleport + damp * qv;
        }
    }
    p
}

/// Hash-permuted, edge-partitioned shards ready for distributed PageRank
/// (shared by the sequential driver below and the threaded coordinator).
pub struct PageRankShards {
    pub shards: Vec<Csr>,
    pub hasher: IndexHasher,
    pub vertices: i64,
}

impl PageRankShards {
    pub fn build(graph: &EdgeList, machines: usize, seed: u64) -> PageRankShards {
        let hasher = IndexHasher::pagerank(graph.vertices as u64, seed);
        let permuted = graph.permute(|v| hasher.hash(v));
        let outdeg = permuted.out_degrees();
        let shards_edges = random_edge_partition(&permuted.edges, machines, seed);
        let shards: Vec<Csr> = shards_edges
            .iter()
            .map(|es| Csr::from_edges(es, |u| 1.0 / outdeg[u as usize].max(1) as f32))
            .collect();
        PageRankShards { shards, hasher, vertices: graph.vertices }
    }

    pub fn outbound(&self) -> Vec<IndexSet> {
        self.shards.iter().map(|s| IndexSet::from_sorted(s.row_globals.clone())).collect()
    }

    pub fn inbound(&self) -> Vec<IndexSet> {
        self.shards.iter().map(|s| IndexSet::from_sorted(s.col_globals.clone())).collect()
    }
}

/// Distributed PageRank instance (sequential lockstep driver; the
/// coordinator module runs the same shards on the threaded cluster).
pub struct DistPageRank {
    pub shards: Vec<Csr>,
    cluster: LocalCluster,
    /// Current P values per node, aligned with the node's inbound
    /// (source-vertex) set.
    p_local: Vec<Vec<f32>>,
    n: i64,
    /// Vertex permutation applied before partitioning (paper §III-A).
    pub hasher: IndexHasher,
    /// Config-phase message trace (index plumbing, once).
    pub config_trace: Trace,
    /// Per-iteration reduce traces.
    pub iter_traces: Vec<Trace>,
    iters_done: usize,
}

impl DistPageRank {
    /// Partition `graph` across `topo.machines()` machines and run config.
    pub fn new(graph: &EdgeList, degrees: Vec<usize>, cfg: &PageRankConfig) -> DistPageRank {
        let n = graph.vertices;
        let m: usize = degrees.iter().product();
        let built = PageRankShards::build(graph, m, cfg.seed);
        let topo = Butterfly::new(degrees, n);
        let mut cluster = LocalCluster::new(topo);
        let config_trace = cluster.config(built.outbound(), built.inbound());

        let teleport = 1.0f32 / n as f32;
        let p_local: Vec<Vec<f32>> =
            built.shards.iter().map(|s| vec![teleport; s.cols()]).collect();
        DistPageRank {
            shards: built.shards,
            cluster,
            p_local,
            n,
            hasher: built.hasher,
            config_trace,
            iter_traces: Vec::new(),
            iters_done: 0,
        }
    }

    /// Lockstep driver over pre-built shard CSRs — e.g. streamed from a
    /// `sar shard` directory ([`crate::graph::load_all_shards`]) — so the
    /// lockstep oracle can anchor the cross-mode determinism checksum for
    /// on-disk shard sets too. `hasher` must be the permutation the
    /// shards were written under ([`IndexHasher::pagerank`]) for
    /// [`DistPageRank::score_of`] lookups to resolve.
    pub fn from_shards(
        shards: Vec<Csr>,
        vertices: i64,
        degrees: Vec<usize>,
        hasher: IndexHasher,
    ) -> anyhow::Result<DistPageRank> {
        let m: usize = degrees.iter().product();
        if shards.len() != m {
            anyhow::bail!(
                "degree schedule {degrees:?} covers {m} machines but {} shards were given",
                shards.len()
            );
        }
        let topo = Butterfly::new(degrees, vertices);
        let mut cluster = LocalCluster::new(topo);
        let outbound: Vec<IndexSet> =
            shards.iter().map(|s| IndexSet::from_sorted(s.row_globals.clone())).collect();
        let inbound: Vec<IndexSet> =
            shards.iter().map(|s| IndexSet::from_sorted(s.col_globals.clone())).collect();
        let config_trace = cluster.config(outbound, inbound);
        let teleport = 1.0f32 / vertices as f32;
        let p_local: Vec<Vec<f32>> = shards.iter().map(|s| vec![teleport; s.cols()]).collect();
        Ok(DistPageRank {
            shards,
            cluster,
            p_local,
            n: vertices,
            hasher,
            config_trace,
            iter_traces: Vec::new(),
            iters_done: 0,
        })
    }

    pub fn machines(&self) -> usize {
        self.shards.len()
    }

    pub fn iterations_done(&self) -> usize {
        self.iters_done
    }

    /// Run one PageRank iteration; returns the reduce trace.
    pub fn step(&mut self) -> &Trace {
        let q: Vec<Vec<f32>> =
            self.shards.iter().zip(&self.p_local).map(|(s, p)| s.spmv(p)).collect();
        let (sums, trace) = self.cluster.reduce::<SumF32>(q);
        for (pl, sv) in self.p_local.iter_mut().zip(sums) {
            apply_update(pl, &sv, self.n);
        }
        self.iters_done += 1;
        self.iter_traces.push(trace);
        self.iter_traces.last().unwrap()
    }

    /// Run `iters` iterations.
    pub fn run(&mut self, iters: usize) {
        for _ in 0..iters {
            self.step();
        }
    }

    /// Sum of each node's `p[0]` — the determinism probe every driver
    /// (threaded coordinator, multi-process cluster) also reports, so
    /// runs over different transports can be checked for equality.
    pub fn checksum(&self) -> f64 {
        self.p_local.iter().map(|p| p.first().copied().unwrap_or(0.0) as f64).sum()
    }

    /// Current score of an *original* (pre-permutation) vertex id, if some
    /// shard tracks it (its hashed id appears as a source vertex).
    pub fn score_of(&self, orig_vertex: i64) -> Option<f32> {
        let hashed = self.hasher.hash(orig_vertex);
        for (shard, pl) in self.shards.iter().zip(&self.p_local) {
            if let Ok(pos) = shard.col_globals.binary_search(&hashed) {
                return Some(pl[pos]);
            }
        }
        None
    }

    /// Total values reduced per iteration (the paper's throughput
    /// numerator, §VI-B: "total billions of input values reduced/sec").
    pub fn reduce_input_len(&self) -> usize {
        self.shards.iter().map(|s| s.rows()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_power_law, GraphGenParams};

    fn small_graph(seed: u64) -> EdgeList {
        generate_power_law(&GraphGenParams {
            vertices: 600,
            edges: 4_000,
            alpha_out: 1.2,
            alpha_in: 1.2,
            seed,
        })
    }

    #[test]
    fn serial_pagerank_is_a_distribution_like_vector() {
        let g = small_graph(1);
        let p = serial_pagerank(&g, 10);
        // all positive, finite
        assert!(p.iter().all(|&x| x > 0.0 && x.is_finite()));
        // hubs (high in-degree) score above the floor
        let indeg = g.in_degrees();
        let (hub, _) = indeg.iter().enumerate().max_by_key(|(_, &d)| d).unwrap();
        assert!(p[hub] > 2.0 / 600.0, "hub score {} too low", p[hub]);
    }

    fn check_dist_matches_serial(degrees: Vec<usize>, iters: usize, seed: u64) {
        let g = small_graph(seed);
        // oracle on the *permuted* graph is the same as comparing through
        // the hasher; run serial on raw graph and look up via score_of.
        let serial = serial_pagerank(&g, iters);
        let mut dist = DistPageRank::new(&g, degrees.clone(), &PageRankConfig { seed, iters });
        dist.run(iters);
        let mut checked = 0usize;
        for v in 0..g.vertices {
            if let Some(score) = dist.score_of(v) {
                let want = serial[v as usize];
                assert!(
                    (score - want).abs() < 1e-5 + want * 1e-3,
                    "degrees {degrees:?} vertex {v}: dist {score} vs serial {want}"
                );
                checked += 1;
            }
        }
        assert!(checked > 100, "too few vertices checked: {checked}");
    }

    #[test]
    fn distributed_matches_serial_4_machines() {
        check_dist_matches_serial(vec![4], 5, 11);
        check_dist_matches_serial(vec![2, 2], 5, 11);
    }

    #[test]
    fn distributed_matches_serial_8_machines() {
        check_dist_matches_serial(vec![4, 2], 8, 13);
        check_dist_matches_serial(vec![2, 2, 2], 8, 13);
    }

    #[test]
    fn single_machine_degenerate() {
        check_dist_matches_serial(vec![1], 3, 17);
    }

    #[test]
    fn from_shards_matches_new_bit_exactly() {
        let g = small_graph(29);
        let iters = 4;
        let mut a = DistPageRank::new(&g, vec![2, 2], &PageRankConfig { seed: 29, iters });
        a.run(iters);
        let built = PageRankShards::build(&g, 4, 29);
        let mut b =
            DistPageRank::from_shards(built.shards, g.vertices, vec![2, 2], built.hasher)
                .unwrap();
        b.run(iters);
        assert_eq!(a.checksum(), b.checksum(), "same shards must give the same checksum");
        assert!(
            DistPageRank::from_shards(Vec::new(), 10, vec![2, 2], IndexHasher::pagerank(10, 1))
                .is_err(),
            "shard count must match the degree schedule"
        );
    }

    #[test]
    fn traces_accumulate_per_iteration() {
        let g = small_graph(19);
        let mut dist = DistPageRank::new(&g, vec![2, 2], &PageRankConfig::default());
        dist.run(3);
        assert_eq!(dist.iter_traces.len(), 3);
        assert!(dist.config_trace.total_bytes() > 0);
        // static graph → identical communication structure every iteration
        assert_eq!(dist.iter_traces[0].len(), dist.iter_traces[1].len());
        assert_eq!(dist.iter_traces[0].total_bytes(), dist.iter_traces[2].total_bytes());
    }
}
