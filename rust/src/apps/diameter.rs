//! HADI-style diameter estimation (paper §I-A2, eq. 3).
//!
//! Each vertex carries a neighbourhood sketch; one iteration replaces the
//! sketch with the OR of its in-neighbours' sketches (plus its own), i.e.
//! `b^{h+1} = G ×_or b^h` — implemented with the *same* Sparse Allreduce
//! machinery as PageRank, just with the [`OrU32`] reduce operator.
//!
//! The per-node state machine lives in [`DiameterNode`]: the node's edge
//! shard, the vertices it tracks, and its current sketches. Every
//! execution mode drives the identical node engine — the in-process
//! drivers ([`estimate_diameter`] and the comm-session job runner) build
//! all `m` nodes in one process, a multi-process worker builds only its
//! own ([`DiameterNode::build_one`]) — so the determinism probe
//! ([`DiameterNode::probe`]) is comparable across lockstep, threaded and
//! multi-process runs.
//!
//! Two sketch modes:
//! * **Exact** (graphs ≤ 32 vertices): sketch = one-hot vertex bitmask, so
//!   the iteration computes exact reachability sets — used to validate the
//!   OR-allreduce end to end against a BFS oracle.
//! * **Flajolet–Martin** (any size): `K` 32-bit FM sketches per vertex;
//!   the neighbourhood function `N(h)` is estimated as
//!   `2^{b̄}/0.77351` where `b̄` is the mean position of the lowest zero
//!   bit; the effective diameter is the smallest `h` with
//!   `N(h) ≥ 0.9·N(h_max)`.

use crate::comm::{ExecMode, Session};
use crate::graph::{Csr, EdgeList};
use crate::partition::random_edge_partition;
use crate::sparse::{spvec_from_pairs, IndexSet, OrU32};
use crate::util::Pcg32;
use anyhow::Result;

/// Diameter estimation parameters.
#[derive(Clone, Copy, Debug)]
pub struct DiameterConfig {
    /// FM sketches per vertex (ignored in exact mode).
    pub k_sketches: usize,
    /// Maximum hops to run.
    pub max_h: usize,
    /// Exact one-hot mode (requires vertices ≤ 32).
    pub exact: bool,
    pub seed: u64,
}

impl Default for DiameterConfig {
    fn default() -> Self {
        Self { k_sketches: 8, max_h: 32, exact: false, seed: 7 }
    }
}

/// Result of a diameter run.
#[derive(Clone, Debug)]
pub struct DiameterResult {
    /// Estimated neighbourhood function N(h) for h = 1..=H.
    pub neighbourhood: Vec<f64>,
    /// Effective diameter (90th-percentile saturation).
    pub effective_diameter: usize,
    /// Hops actually executed (stops early on saturation).
    pub hops_run: usize,
}

/// FM magic constant.
const FM_PHI: f64 = 0.77351;

fn fm_sketch(rng: &mut Pcg32) -> u32 {
    // set bit i with probability 2^-(i+1): geometric position of the first
    // success in a fair-coin sequence.
    let r = rng.next_u32();
    let pos = r.trailing_ones(); // P(pos = i) = 2^-(i+1)
    1u32 << pos.min(31)
}

fn lowest_zero_bit(x: u32) -> u32 {
    (!x).trailing_zeros()
}

/// Estimate N from K sketches: 2^mean(lowest-zero) / phi.
fn estimate_count(sketches: &[u32]) -> f64 {
    let mean: f64 =
        sketches.iter().map(|&s| lowest_zero_bit(s) as f64).sum::<f64>() / sketches.len() as f64;
    2f64.powf(mean) / FM_PHI
}

/// One logical node's share of a diameter run: its edge shard, the
/// vertices it tracks (rows ∪ cols; node 0 tracks everything so it can
/// evaluate N(h)), and its current sketches aligned with
/// `tracked × K`. Vertex `v`'s `K` sketches live at allreduce indices
/// `v·K + j`.
pub struct DiameterNode {
    shard: Csr,
    tracked: Vec<i64>,
    k: usize,
    exact: bool,
    vertices: i64,
    cur: Vec<u32>,
}

impl DiameterNode {
    /// Build every node's engine (in-process drivers). Deterministic in
    /// `(graph, m, cfg.seed)`: the edge partition and the global init
    /// sketch sequence are both seeded, so a multi-process worker
    /// rebuilding only its own node lands on identical state.
    pub fn build_all(graph: &EdgeList, m: usize, cfg: &DiameterConfig) -> Vec<DiameterNode> {
        let n = graph.vertices;
        let k = if cfg.exact { 1 } else { cfg.k_sketches };
        assert!(!cfg.exact || n <= 32, "exact mode needs ≤ 32 vertices");
        let shards_edges = random_edge_partition(&graph.edges, m, cfg.seed);
        let shards: Vec<Csr> =
            shards_edges.iter().map(|es| Csr::from_edges(es, |_| 1.0)).collect();

        // initial sketches for every vertex, one global RNG sequence
        let mut rng = Pcg32::new(cfg.seed ^ 0xD1A);
        let init: Vec<Vec<u32>> = (0..n)
            .map(|v| {
                (0..k)
                    .map(|_| if cfg.exact { 1u32 << (v as u32) } else { fm_sketch(&mut rng) })
                    .collect()
            })
            .collect();

        shards
            .into_iter()
            .enumerate()
            .map(|(node, shard)| {
                let tracked: Vec<i64> = if node == 0 {
                    (0..n).collect()
                } else {
                    let mut v = shard.row_globals.clone();
                    v.extend_from_slice(&shard.col_globals);
                    v.sort_unstable();
                    v.dedup();
                    v
                };
                let cur: Vec<u32> =
                    tracked.iter().flat_map(|&v| init[v as usize].clone()).collect();
                DiameterNode { shard, tracked, k, exact: cfg.exact, vertices: n, cur }
            })
            .collect()
    }

    /// Build one node's engine (multi-process workers): partitions the
    /// same regenerated edge list and keeps only shard `node`.
    pub fn build_one(graph: &EdgeList, m: usize, node: usize, cfg: &DiameterConfig) -> DiameterNode {
        let mut all = Self::build_all(graph, m, cfg);
        all.swap_remove(node)
    }

    /// Sketches per vertex actually in use (1 in exact mode).
    pub fn sketches(&self) -> usize {
        self.k
    }

    /// The allreduce index domain: `vertices × K`.
    pub fn index_range(&self) -> i64 {
        self.vertices * self.k as i64
    }

    /// The node's contributed *and* requested index set (`tracked × K`
    /// expanded): contributing a vertex's old sketch keeps `b^h` monotone
    /// (self-retention) and is free under idempotent OR.
    pub fn index_set(&self) -> IndexSet {
        let mut out = Vec::with_capacity(self.tracked.len() * self.k);
        for &v in &self.tracked {
            for j in 0..self.k as i64 {
                out.push(v * self.k as i64 + j);
            }
        }
        IndexSet::from_sorted(out)
    }

    /// This hop's outbound values: every tracked vertex's old sketch
    /// (self-retention) merged with the OR-SpMV of the shard's rows.
    pub fn contribution(&self) -> Vec<u32> {
        let k = self.k;
        let t = &self.tracked;
        let pos_of = |v: i64| t.binary_search(&v).expect("tracked vertex") * k;
        // cols slice of the node's current sketches
        let cols: Vec<u32> = self
            .shard
            .col_globals
            .iter()
            .flat_map(|&v| {
                let p = pos_of(v);
                self.cur[p..p + k].to_vec()
            })
            .collect();
        // sketch-wise OR-SpMV: for slot j, input = cols of slot j
        let mut qs: Vec<Vec<u32>> = Vec::with_capacity(k);
        for j in 0..k {
            let slice: Vec<u32> = (0..self.shard.cols()).map(|c| cols[c * k + j]).collect();
            qs.push(self.shard.spmv_or(&slice));
        }
        let mut pairs: Vec<(i64, u32)> = Vec::new();
        for (p, &v) in t.iter().enumerate() {
            for j in 0..k {
                pairs.push((v * k as i64 + j as i64, self.cur[p * k + j]));
            }
        }
        for (r, &v) in self.shard.row_globals.iter().enumerate() {
            for j in 0..k {
                pairs.push((v * k as i64 + j as i64, qs[j][r]));
            }
        }
        spvec_from_pairs::<OrU32>(pairs).val
    }

    /// Absorb the reduced sketches (aligned with [`DiameterNode::index_set`]).
    pub fn absorb(&mut self, reduced: Vec<u32>) {
        assert_eq!(reduced.len(), self.tracked.len() * self.k, "reduced sketch length");
        self.cur = reduced;
    }

    /// The cross-mode determinism probe: the node's first tracked sketch
    /// (the diameter analogue of PageRank's `p[0]`). Exact as f64 for
    /// any u32, so summing probes across nodes is order-independent.
    pub fn probe(&self) -> f64 {
        self.cur.first().copied().unwrap_or(0) as f64
    }

    /// Evaluate the neighbourhood function over all vertices — only the
    /// all-vertex tracker (node 0) can answer this.
    pub fn neighbourhood_estimate(&self) -> f64 {
        assert_eq!(
            self.tracked.len() as i64,
            self.vertices,
            "N(h) evaluation needs the all-vertex tracker (node 0)"
        );
        let mut total = 0f64;
        for v in 0..self.vertices as usize {
            let sk = &self.cur[v * self.k..(v + 1) * self.k];
            total += if self.exact {
                sk[0].count_ones() as f64
            } else {
                estimate_count(sk)
            };
        }
        total
    }
}

/// Sum of per-node probes: the checksum every execution mode reports.
pub fn diameter_checksum(nodes: &[DiameterNode]) -> f64 {
    nodes.iter().map(|n| n.probe()).sum()
}

/// Run distributed HADI through a communicator session of the given
/// mode (lockstep or threaded). Stops early once N(h) saturates.
pub fn estimate_diameter_mode(
    graph: &EdgeList,
    degrees: Vec<usize>,
    cfg: &DiameterConfig,
    mode: ExecMode,
) -> Result<DiameterResult> {
    let m: usize = degrees.iter().product();
    let mut nodes = DiameterNode::build_all(graph, m, cfg);
    let range = nodes[0].index_range();
    let mut session = Session::new_in_process(mode, degrees, 4, range.max(1), None)?;
    let sets: Vec<IndexSet> = nodes.iter().map(|n| n.index_set()).collect();
    let mut handle = session.configure(sets.clone(), sets)?;

    let mut neighbourhood = Vec::new();
    let mut hops = 0usize;
    for _h in 1..=cfg.max_h {
        let mut vals: Vec<Vec<u32>> = nodes.iter().map(|n| n.contribution()).collect();
        handle.allreduce::<OrU32>(&mut vals)?;
        for (node, v) in nodes.iter_mut().zip(vals) {
            node.absorb(v);
        }
        hops += 1;

        let total = nodes[0].neighbourhood_estimate();
        neighbourhood.push(total);
        // saturation: stop when N stops growing
        if neighbourhood.len() >= 2 {
            let prev = neighbourhood[neighbourhood.len() - 2];
            if (total - prev).abs() < 1e-9 {
                break;
            }
        }
    }

    let n_max = *neighbourhood.last().unwrap();
    let effective = neighbourhood
        .iter()
        .position(|&x| x >= 0.9 * n_max)
        .map(|i| i + 1)
        .unwrap_or(hops);
    Ok(DiameterResult { neighbourhood, effective_diameter: effective, hops_run: hops })
}

/// Run distributed HADI on the lockstep oracle (the historical entry
/// point; in-process collectives cannot fail).
pub fn estimate_diameter(
    graph: &EdgeList,
    degrees: Vec<usize>,
    cfg: &DiameterConfig,
) -> DiameterResult {
    estimate_diameter_mode(graph, degrees, cfg, ExecMode::Lockstep)
        .expect("in-process diameter run failed")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: i64) -> EdgeList {
        EdgeList { vertices: n, edges: (0..n - 1).map(|i| (i, i + 1)).collect() }
    }

    #[test]
    fn fm_sketch_bit_distribution() {
        let mut rng = Pcg32::new(3);
        let mut bit0 = 0usize;
        let trials = 100_000;
        for _ in 0..trials {
            if fm_sketch(&mut rng) & 1 != 0 {
                bit0 += 1;
            }
        }
        let frac = bit0 as f64 / trials as f64;
        assert!((frac - 0.5).abs() < 0.01, "P(bit0) = {frac}, want 0.5");
    }

    #[test]
    fn estimate_count_scales() {
        // a sketch with low bits set up to position p estimates ~2^p
        let small = estimate_count(&[0b1]);
        let large = estimate_count(&[0b1111_1111]);
        assert!(large > 50.0 * small);
    }

    #[test]
    fn exact_path_graph_diameter() {
        // path 0→1→…→9: in-neighbourhood of vertex 9 saturates after 9
        // hops; the exact neighbourhood function must grow for 9 rounds.
        let g = path_graph(10);
        let res = estimate_diameter(
            &g,
            vec![2],
            &DiameterConfig { exact: true, max_h: 20, seed: 1, k_sketches: 1 },
        );
        // N(h) for a path: sum over v of min(h, v)+1 … saturates at h = 9.
        assert_eq!(res.hops_run, 10, "should saturate exactly after the 9-hop diameter");
        // exact N(h_max) = sum over v of (v+1) = 55
        assert_eq!(*res.neighbourhood.last().unwrap() as i64, 55);
        let mono = res.neighbourhood.windows(2).all(|w| w[1] >= w[0]);
        assert!(mono, "neighbourhood function must be monotone");
    }

    #[test]
    fn exact_matches_bfs_oracle_random_digraph() {
        let mut rng = Pcg32::new(5);
        let n = 20i64;
        let edges: Vec<(i64, i64)> = (0..60)
            .map(|_| {
                loop {
                    let u = rng.gen_range(0, n as usize) as i64;
                    let v = rng.gen_range(0, n as usize) as i64;
                    if u != v {
                        return (u, v);
                    }
                }
            })
            .collect();
        let g = EdgeList { vertices: n, edges };
        let res = estimate_diameter(
            &g,
            vec![2, 2],
            &DiameterConfig { exact: true, max_h: 30, seed: 2, k_sketches: 1 },
        );
        // BFS oracle: N(h) = Σ_v |{u : u reaches v within h hops}| over
        // in-edges (including v itself).
        let mut reach: Vec<u32> = (0..n).map(|v| 1u32 << v).collect();
        let mut oracle = Vec::new();
        for _h in 0..res.hops_run {
            let mut next = reach.clone();
            for &(u, v) in &g.edges {
                next[v as usize] |= reach[u as usize];
            }
            reach = next;
            oracle.push(reach.iter().map(|r| r.count_ones() as f64).sum::<f64>());
        }
        assert_eq!(res.neighbourhood.len(), oracle.len());
        for (got, want) in res.neighbourhood.iter().zip(&oracle) {
            assert_eq!(*got as i64, *want as i64);
        }
    }

    #[test]
    fn fm_mode_reasonable_on_star() {
        // star: all vertices point at 0 → everyone is within 1 hop of 0;
        // effective diameter should be small.
        let n = 200i64;
        let edges: Vec<(i64, i64)> = (1..n).map(|v| (v, 0)).collect();
        let g = EdgeList { vertices: n, edges };
        let res = estimate_diameter(
            &g,
            vec![2, 2],
            &DiameterConfig { exact: false, k_sketches: 16, max_h: 10, seed: 3 },
        );
        assert!(res.effective_diameter <= 2, "star diameter {}", res.effective_diameter);
        // FM estimate of the saturated neighbourhood should be within 3x
        // of the truth (N_true = 2n - 1 = 399: vertex 0 sees everyone,
        // others see themselves).
        let n_est = *res.neighbourhood.last().unwrap();
        assert!(
            (100.0..1600.0).contains(&n_est),
            "FM estimate {n_est} too far from 399"
        );
    }

    #[test]
    fn threaded_mode_matches_lockstep_hop_for_hop() {
        let g = path_graph(12);
        let cfg = DiameterConfig { exact: false, k_sketches: 4, max_h: 6, seed: 9 };
        let a = estimate_diameter_mode(&g, vec![2, 2], &cfg, ExecMode::Lockstep).unwrap();
        let b = estimate_diameter_mode(&g, vec![2, 2], &cfg, ExecMode::Threaded).unwrap();
        assert_eq!(a.hops_run, b.hops_run);
        assert_eq!(a.neighbourhood, b.neighbourhood, "N(h) must be bit-identical");
    }

    #[test]
    fn build_one_matches_build_all() {
        let g = path_graph(16);
        let cfg = DiameterConfig { exact: false, k_sketches: 2, max_h: 4, seed: 11 };
        let all = DiameterNode::build_all(&g, 4, &cfg);
        for node in 0..4 {
            let one = DiameterNode::build_one(&g, 4, node, &cfg);
            assert_eq!(one.tracked, all[node].tracked, "node {node} tracked set");
            assert_eq!(one.cur, all[node].cur, "node {node} init sketches");
            assert_eq!(one.contribution(), all[node].contribution(), "node {node}");
        }
    }
}
