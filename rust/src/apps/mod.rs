//! Applications driven by Sparse Allreduce (paper §I-A, §III-B, §VI-E).
//!
//! * [`pagerank`] — the paper's headline benchmark: distributed PageRank
//!   where each iteration's matrix-vector product is assembled with one
//!   sparse (sum) allreduce; config runs once (static graph).
//! * [`diameter`] — HADI diameter estimation: Flajolet–Martin
//!   neighbourhood sketches combined with a bitwise-OR allreduce.
//! * [`sgd`] — mini-batch sub-gradient training over a sharded sparse
//!   model: dynamic config every step, gradients scatter-reduced into
//!   per-owner model shards at the bottom of the butterfly, fresh model
//!   values allgathered back (the paper's mini-batch use case).

pub mod diameter;
pub mod pagerank;
pub mod sgd;

pub use diameter::{DiameterConfig, DiameterResult};
pub use pagerank::{serial_pagerank, DistPageRank, PageRankConfig, PageRankShards};
pub use sgd::{GradEngine, NativeGradEngine, SgdConfig, Trainer};
